//! τ — the range-to-range contribution primitive (Lemma 1) and its four
//! implementations (paper §5.2), plus the calibrated Hybrid (§5.3).
//!
//! | paper (GPU)      | here                | cost      | wins at |
//! |------------------|---------------------|-----------|---------|
//! | Conv1D           | `PjrtDirect` (Pallas direct-tile artifact) | O(U²D)      | framework-dispatched quadratic point |
//! | FlashConv1D      | `RustDirect` (native, allocation-free)     | O(U²D)      | small U (no dispatch overhead) |
//! | FFT (torch)      | `PjrtFft` (jnp.fft artifact)               | O(U log U D)| framework-dispatched quasilinear point |
//! | FlashFFT         | `RustFft` (native vec-rfft, cached half-spectrum ρ̂) | O(U log U D)| large U |
//!
//! All four accumulate the tile `pending[g, i+1..i+U] += τ(streams[g,
//! i-U+1..i], ρ_m)` for every group `g = m·B + b` — one call covers all
//! layers (Algorithm 3's across-layer parallelism, realized as batching;
//! the native impls additionally fan groups across a thread pool).

pub mod async_exec;
pub mod calibrate;
pub mod hybrid;
pub mod pjrt_direct;
pub mod pjrt_fft;
pub mod rho_cache;
pub mod rust_direct;
pub mod rust_fft;

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::engine::store::RowReadiness;
use crate::tiling::{flops, Tile};
use crate::util::tensor::CellTensor;

pub use async_exec::AsyncTau;
pub use calibrate::{calibrate, CalibrationTable};
pub use hybrid::Hybrid;
pub use pjrt_direct::PjrtDirect;
pub use pjrt_fft::PjrtFft;
pub use rho_cache::RhoCache;
pub use rust_direct::RustDirect;
pub use rust_fft::RustFft;

/// Which τ implementation to use.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TauKind {
    RustDirect,
    RustFft,
    PjrtDirect,
    PjrtFft,
    /// Per-tile-size dynamic choice (paper's best method).
    Hybrid,
}

impl TauKind {
    pub const ALL_FIXED: [TauKind; 4] =
        [TauKind::RustDirect, TauKind::RustFft, TauKind::PjrtDirect, TauKind::PjrtFft];

    pub fn parse(s: &str) -> Result<TauKind> {
        Ok(match s {
            "rust-direct" => TauKind::RustDirect,
            "rust-fft" => TauKind::RustFft,
            "pjrt-direct" => TauKind::PjrtDirect,
            "pjrt-fft" => TauKind::PjrtFft,
            "hybrid" => TauKind::Hybrid,
            other => anyhow::bail!(
                "unknown tau impl '{other}' (rust-direct|rust-fft|pjrt-direct|pjrt-fft|hybrid)"
            ),
        })
    }

    pub fn as_str(self) -> &'static str {
        match self {
            TauKind::RustDirect => "rust-direct",
            TauKind::RustFft => "rust-fft",
            TauKind::PjrtDirect => "pjrt-direct",
            TauKind::PjrtFft => "pjrt-fft",
            TauKind::Hybrid => "hybrid",
        }
    }

    /// FLOPs one tile of side `u` costs under this implementation
    /// (per Proposition 1 / §5.4(1) accounting; Hybrid is charged the FFT
    /// closed form — its dispatch table resolves at runtime). Both FFT
    /// kinds run real-input half-spectrum pipelines (`fft::rfft` natively,
    /// jnp.rfft in the artifact), so they are charged the rfft model.
    pub fn tile_flops(self, u: usize, g: usize, d: usize) -> u64 {
        match self {
            TauKind::RustDirect | TauKind::PjrtDirect => {
                flops::tile_direct_flops(u, d) * g as u64
            }
            TauKind::RustFft | TauKind::PjrtFft | TauKind::Hybrid => {
                flops::tile_rfft_flops(u, d) * g as u64
            }
        }
    }
}

/// What one fence call observed (exposed-stall instrumentation).
#[derive(Debug, Default, Clone, Copy)]
pub struct FenceStats {
    /// Wall time the caller was blocked waiting for in-flight tiles.
    pub wait_ns: u64,
    /// In-flight tiles the fence had to wait on (0 ⇒ fully hidden).
    pub jobs_waited: usize,
}

impl FenceStats {
    pub fn absorb(&mut self, other: FenceStats) {
        self.wait_ns += other.wait_ns;
        self.jobs_waited += other.jobs_waited;
    }
}

/// One τ implementation: accumulate a gray tile into `pending`.
///
/// `streams` and `pending` are `[G, L, D]` [`CellTensor`] planes (shared
/// with any in-flight async jobs — see `util::tensor`); `tile` carries
/// 1-indexed absolute ranges (row `t` of a group = position `t+1`).
/// Implementations write `pending` through the unsafe cell accessors
/// under the deadline contract below: the submitted tile's destination
/// rows are theirs exclusively until the corresponding fence.
///
/// ## Submit/fence semantics (deadline-fenced execution)
///
/// [`TauImpl::submit`] hands a tile to the implementation with the
/// *deadline contract*: the tile's outputs `pending[dst_l..=dst_r]` need
/// not exist until a later [`TauImpl::fence`] names one of those columns —
/// `z[i+1..i+U]` is first consumed at iteration `i+1` at the earliest
/// (Algorithm 1's availability invariant), so the executor may run the
/// tile concurrently with everything the caller does in between. The
/// caller promises in return not to mutate the tile's source rows or read
/// its destination rows until the corresponding fence has drained.
///
/// Synchronous implementations satisfy the contract trivially: the
/// default `submit` is `apply` and the default `fence` is a no-op, so
/// every pre-existing impl (and any future one) composes with the
/// session's submit/fence call sites unchanged.
pub trait TauImpl {
    fn kind(&self) -> TauKind;

    fn apply(&mut self, streams: &CellTensor, pending: &CellTensor, tile: Tile) -> Result<()>;

    /// FLOPs this impl spends on a side-`u` tile (for the FlopCounter).
    fn tile_flops(&self, u: usize, g: usize, d: usize) -> u64 {
        self.kind().tile_flops(u, g, d)
    }

    /// Submit a tile under the deadline contract above. The planes come
    /// as `Arc`s so an asynchronous impl can hand clones to detached
    /// jobs. Default: synchronous `apply` (the tile is complete on
    /// return).
    fn submit(
        &mut self,
        streams: &Arc<CellTensor>,
        pending: &Arc<CellTensor>,
        tile: Tile,
    ) -> Result<()> {
        self.apply(streams, pending, tile)
    }

    /// Block until every submitted tile whose destination range covers
    /// `col` (same 1-indexed row coordinates as the submitted tiles'
    /// `dst_l..=dst_r`) has landed. Default: nothing is ever in flight.
    fn fence(&mut self, _col: usize) -> Result<FenceStats> {
        Ok(FenceStats::default())
    }

    /// Block until *every* submitted tile has landed (session teardown,
    /// or before handing the store to a reader that scans all rows).
    fn fence_all(&mut self) -> Result<FenceStats> {
        Ok(FenceStats::default())
    }

    /// Worker-side τ compute nanoseconds accumulated since the last call
    /// (hidden-vs-exposed mixer accounting). 0 for synchronous impls —
    /// their compute is already on the caller's clock.
    fn take_worker_ns(&mut self) -> u64 {
        0
    }

    /// Attach the store's row-readiness tracker so detached jobs can mark
    /// their destination rows in flight. No-op for synchronous impls.
    fn attach_readiness(&mut self, _readiness: Arc<RowReadiness>) {}
}

/// Construct a τ implementation over a shared rho cache.
pub fn make_impl<'rt, 'c>(
    kind: TauKind,
    cache: &'c RhoCache<'rt>,
    threads: usize,
) -> Result<Box<dyn TauImpl + 'c>> {
    Ok(match kind {
        TauKind::RustDirect => Box::new(RustDirect::new(cache, threads)),
        TauKind::RustFft => Box::new(RustFft::new(cache, threads)),
        TauKind::PjrtDirect => Box::new(PjrtDirect::new(cache)),
        TauKind::PjrtFft => Box::new(PjrtFft::new(cache)),
        TauKind::Hybrid => Box::new(Hybrid::from_default(cache, threads)?),
    })
}

/// Execution policy for the session-facing constructor below.
#[derive(Debug, Clone, Copy)]
pub struct TauExecCfg {
    /// Wrap native impls in the deadline-fenced [`AsyncTau`] executor.
    pub async_mixer: bool,
    /// Split tiles with `U >= split_min_u` into staged-deadline chunks
    /// (0 disables splitting; see `async_exec`).
    pub split_min_u: usize,
    /// Pool workers for the async executor's dependency-tracked queue
    /// (≥ 1; `> 1` requires `async_mixer` over a native kind).
    pub mixer_workers: usize,
}

/// Construct the τ implementation a `Session` drives, applying the async
/// execution policy. The PJRT-backed kinds (including `Hybrid`, which may
/// dispatch to them per tile size) stay synchronous regardless: PJRT
/// handles are not `Send`, so their tiles cannot leave the engine thread.
/// Requesting `mixer_workers > 1` for a configuration that cannot run
/// multi-worker is a hard error, not a silent fallback — a serving config
/// that asks for concurrency should not quietly lose it.
pub fn make_session_impl<'rt, 'c>(
    kind: TauKind,
    cache: &'c RhoCache<'rt>,
    threads: usize,
    exec: TauExecCfg,
) -> Result<Box<dyn TauImpl + 'c>> {
    if exec.mixer_workers == 0 {
        bail!("mixer_workers must be >= 1 (use --sync-mixer to disable async execution)");
    }
    let native = matches!(kind, TauKind::RustDirect | TauKind::RustFft);
    if exec.async_mixer && native {
        let sync = make_impl(kind, cache, threads)?;
        return Ok(Box::new(AsyncTau::new(cache, sync, exec.split_min_u, exec.mixer_workers)));
    }
    if exec.mixer_workers > 1 {
        bail!(
            "mixer_workers = {} requires the async mixer over a native tau kind \
             (rust-direct|rust-fft); '{}' with async_mixer = {} runs synchronously \
             on the engine thread — set mixer_workers = 1",
            exec.mixer_workers,
            kind.as_str(),
            exec.async_mixer,
        );
    }
    make_impl(kind, cache, threads)
}

/// Stage the tile's input block `streams[g, src_l-1 .. src_r]` for all
/// groups into a `[G, U, D]` scratch (PJRT impls need one contiguous
/// buffer; per-group rows are already contiguous).
pub fn stage_y(streams: &CellTensor, tile: Tile, buf: &mut Vec<f32>) {
    let (g, d) = (streams.shape()[0], streams.shape()[2]);
    let u = tile.u;
    // every row is copied in, so grown capacity must not be zero-filled
    // first (resize would); clear keeps the allocation, extend appends raw
    buf.clear();
    buf.reserve(g * u * d);
    for gi in 0..g {
        buf.extend_from_slice(streams.block(gi, tile.src_l - 1, tile.src_r));
    }
}

/// Accumulate a `[G, U, D]` tau output into `pending[g, dst_l-1 .. dst_r]`.
pub fn scatter_add(pending: &CellTensor, tile: Tile, vals: &[f32]) {
    let (g, d) = (pending.shape()[0], pending.shape()[2]);
    let u = tile.u;
    debug_assert_eq!(vals.len(), g * u * d);
    for gi in 0..g {
        // SAFETY: callers are synchronous impls running on the engine
        // thread under the deadline contract — the tile's destination
        // rows are exclusively theirs (no detached jobs exist for PJRT
        // kinds, and sync native `apply` only runs after a full drain).
        let dst = unsafe { pending.block_mut(gi, tile.dst_l - 1, tile.dst_r) };
        crate::util::tensor::ops::add_assign(dst, &vals[gi * u * d..(gi + 1) * u * d]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kind_parse_roundtrip() {
        for k in TauKind::ALL_FIXED.iter().chain([TauKind::Hybrid].iter()) {
            assert_eq!(TauKind::parse(k.as_str()).unwrap(), *k);
        }
        assert!(TauKind::parse("cuda").is_err());
    }

    #[test]
    fn stage_and_scatter_are_inverse_shaped() {
        use crate::util::tensor::Tensor;
        let (g, l, d) = (2usize, 8usize, 3usize);
        let mut base = Tensor::zeros(&[g, l, d]);
        for (i, v) in base.data_mut().iter_mut().enumerate() {
            *v = i as f32;
        }
        let streams = CellTensor::from_tensor(&base);
        let tile = Tile::at(4); // u=4: src [1,4], dst [5,8]
        let mut buf = Vec::new();
        stage_y(&streams, tile, &mut buf);
        assert_eq!(buf.len(), g * 4 * d);
        assert_eq!(&buf[..d], streams.at2(0, 0));

        let pending = CellTensor::zeros(&[g, l, d]);
        scatter_add(&pending, tile, &buf);
        assert_eq!(pending.at2(0, 4), streams.at2(0, 0));
        assert_eq!(pending.at2(1, 7), streams.at2(1, 3));
        // untouched rows stay zero
        assert!(pending.at2(0, 0).iter().all(|&v| v == 0.0));
    }
}
