//! PJRT FFT τ — the jnp.fft tile artifact (rfft → Pallas split-real
//! spectral multiply → irfft) with the filter DFT pre-uploaded as a
//! persistent device buffer. The paper's framework-FFT point (torch FFT /
//! FlashFFT when fused): quasilinear FLOPs plus dispatch overhead.

use anyhow::Result;

use super::{scatter_add, stage_y, RhoCache, TauImpl, TauKind};
use crate::runtime::Runtime;
use crate::tiling::Tile;
use crate::util::tensor::CellTensor;

pub struct PjrtFft<'c, 'rt> {
    cache: &'c RhoCache<'rt>,
    stage: Vec<f32>,
}

impl<'c, 'rt> PjrtFft<'c, 'rt> {
    pub fn new(cache: &'c RhoCache<'rt>) -> Self {
        PjrtFft { cache, stage: Vec::new() }
    }
}

impl TauImpl for PjrtFft<'_, '_> {
    fn kind(&self) -> TauKind {
        TauKind::PjrtFft
    }

    fn apply(&mut self, streams: &CellTensor, pending: &CellTensor, tile: Tile) -> Result<()> {
        let rt = self.cache.runtime();
        let dims = rt.dims;
        let u = tile.u;
        let bundle = self.cache.pjrt(u)?;

        stage_y(streams, tile, &mut self.stage);
        let yb = rt.upload(&self.stage, &[dims.g, u, dims.d])?;
        let outs = bundle.fft.call(&[&yb])?;
        let vals = Runtime::literal_to_vec(&outs[0], dims.g * u * dims.d)?;
        scatter_add(pending, tile, &vals);
        Ok(())
    }
}
