//! τ calibration (§5.3): micro-bench every implementation at every tile
//! size and persist the per-U winner. `flashinfer calibrate` runs this and
//! writes `<artifacts>/hybrid.json`; Fig 3a is this table's raw data.

use std::path::Path;

use anyhow::{Context, Result};

use super::{make_impl, RhoCache, TauKind};
use crate::tiling::{flops, Tile};
use crate::util::benchkit;
use crate::util::json::Json;
use crate::util::prng::Prng;
use crate::util::tensor::{CellTensor, Tensor};

/// Per-tile-size implementation choice (keyed by log2 U).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CalibrationTable {
    by_log2u: Vec<TauKind>,
}

impl CalibrationTable {
    pub fn new(by_log2u: Vec<TauKind>) -> CalibrationTable {
        assert!(!by_log2u.is_empty());
        CalibrationTable { by_log2u }
    }

    /// Built-in fallback when no calibration has been run: native direct
    /// below the model-predicted direct↔FFT crossover (overhead-bound),
    /// native FFT at and above it (FLOP-bound) — the asymptotics of
    /// DESIGN.md §3's mapping, with the switch point re-derived from the
    /// tile cost models so it tracks kernel changes (e.g. the rfft
    /// half-spectrum pipeline) instead of a hard-coded constant.
    pub fn heuristic(l: usize) -> CalibrationTable {
        let levels = (l / 2).max(1).trailing_zeros() as usize + 1;
        let cross = predicted_crossover();
        let by = (0..levels)
            .map(|q| if (1usize << q) < cross { TauKind::RustDirect } else { TauKind::RustFft })
            .collect();
        CalibrationTable::new(by)
    }

    pub fn choice(&self, u: usize) -> TauKind {
        let q = u.trailing_zeros() as usize;
        self.by_log2u[q.min(self.by_log2u.len() - 1)]
    }

    pub fn levels(&self) -> usize {
        self.by_log2u.len()
    }

    pub fn to_json(&self) -> Json {
        let arr = self
            .by_log2u
            .iter()
            .enumerate()
            .map(|(q, k)| {
                Json::from_pairs(vec![
                    ("u", Json::Num((1u64 << q) as f64)),
                    ("impl", Json::Str(k.as_str().into())),
                ])
            })
            .collect();
        // the simd backend is attribution metadata: a table calibrated on
        // an AVX2 runner does not transfer to a scalar one (the fft-side
        // crossover moves). `load` ignores unknown keys, so old tables
        // and new readers interoperate both ways.
        Json::from_pairs(vec![
            ("table", Json::Arr(arr)),
            ("simd", Json::Str(crate::fft::simd::backend_name().into())),
        ])
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, self.to_json().to_string_pretty())
            .with_context(|| format!("write {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<CalibrationTable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("read {}", path.display()))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let mut by = Vec::new();
        for entry in j.req_arr("table")? {
            let u = entry.req_usize("u")?;
            let kind = TauKind::parse(entry.req_str("impl")?)?;
            let q = u.trailing_zeros() as usize;
            if by.len() <= q {
                by.resize(q + 1, TauKind::RustDirect);
            }
            by[q] = kind;
        }
        Ok(CalibrationTable::new(by))
    }
}

/// Smallest power-of-two U at which the rfft tile cost model undercuts the
/// direct model (D cancels, per group) — the analytic Hybrid crossover.
/// Real machines re-derive it empirically via [`calibrate`]; this is the
/// prior used when no `hybrid.json` exists.
pub fn predicted_crossover() -> usize {
    let mut u = 1usize;
    while u < (1 << 24) {
        if flops::tile_rfft_flops(u, 1) < flops::tile_direct_flops(u, 1) {
            return u;
        }
        u *= 2;
    }
    u
}

/// One measured row of the calibration sweep (Fig 3a data).
#[derive(Debug, Clone)]
pub struct CalRow {
    pub u: usize,
    /// (impl, median ns per tile) in `TauKind::ALL_FIXED` order.
    pub medians_ns: Vec<(TauKind, f64)>,
    pub winner: TauKind,
}

/// Micro-bench all τ impls for every U in [1, max_u] on synthetic data.
pub fn calibrate(
    cache: &RhoCache<'_>,
    max_u: usize,
    warmup: usize,
    runs: usize,
) -> Result<(CalibrationTable, Vec<CalRow>)> {
    let dims = cache.runtime().dims;
    let (g, d) = (dims.g, dims.d);
    let mut rng = Prng::new(0xCA11B);
    let mut rows = Vec::new();
    let mut winners = Vec::new();

    let mut u = 1usize;
    while u <= max_u {
        // a real schedule position with this tile side: i = u
        let tile = Tile::at(u);
        let l_needed = tile.dst_r;
        let mut init = Tensor::zeros(&[g, l_needed, d]);
        rng.fill_normal(init.data_mut(), 1.0);
        let streams = CellTensor::from_tensor(&init);
        let pending = CellTensor::zeros(&[g, l_needed, d]);

        let mut medians = Vec::new();
        for kind in TauKind::ALL_FIXED {
            let mut imp = make_impl(kind, cache, 0)?;
            let stats = benchkit::bench(warmup, runs, || {
                imp.apply(&streams, &pending, tile).expect("tau apply");
            });
            medians.push((kind, stats.median_ns));
        }
        let winner = medians
            .iter()
            .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
            .unwrap()
            .0;
        winners.push(winner);
        rows.push(CalRow { u, medians_ns: medians, winner });
        u *= 2;
    }
    Ok((CalibrationTable::new(winners), rows))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heuristic_table_shape() {
        let t = CalibrationTable::heuristic(4096);
        assert_eq!(t.levels(), 12); // U in 1..2048
        assert_eq!(t.choice(1), TauKind::RustDirect);
        assert_eq!(t.choice(2048), TauKind::RustFft);
        // out-of-range U clamps to the last level
        assert_eq!(t.choice(1 << 20), TauKind::RustFft);
    }

    #[test]
    fn heuristic_switches_at_model_crossover() {
        let cross = predicted_crossover();
        // sanity band: the rfft model pays off well inside the real range
        assert!((4..=512).contains(&cross), "crossover={cross}");
        let t = CalibrationTable::heuristic(4096);
        assert_eq!(t.choice(cross), TauKind::RustFft);
        assert_eq!(t.choice(cross / 2), TauKind::RustDirect);
    }

    #[test]
    fn table_json_roundtrip() {
        let t = CalibrationTable::new(vec![
            TauKind::RustDirect,
            TauKind::PjrtDirect,
            TauKind::RustFft,
            TauKind::PjrtFft,
        ]);
        let dir = std::env::temp_dir().join("fi_cal_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("hybrid.json");
        t.save(&path).unwrap();
        let back = CalibrationTable::load(&path).unwrap();
        assert_eq!(back, t);
    }
}
