//! Native direct-tile τ — the FlashConv1D analogue: quadratic FLOPs but
//! zero dispatch overhead and fully streaming memory access, which makes
//! it the small-U winner on the Hybrid's Pareto frontier (Fig 3a).

use anyhow::Result;

use super::{RhoCache, TauImpl, TauKind};
use crate::fft::tile_conv_direct_into;
use crate::tiling::Tile;
use crate::util::tensor::Tensor;
use crate::util::threadpool::ThreadPool;

pub struct RustDirect<'c, 'rt> {
    cache: &'c RhoCache<'rt>,
    pool: ThreadPool,
}

impl<'c, 'rt> RustDirect<'c, 'rt> {
    pub fn new(cache: &'c RhoCache<'rt>, threads: usize) -> Self {
        RustDirect { cache, pool: ThreadPool::new(threads) }
    }
}

impl TauImpl for RustDirect<'_, '_> {
    fn kind(&self) -> TauKind {
        TauKind::RustDirect
    }

    fn apply(&mut self, streams: &Tensor, pending: &mut Tensor, tile: Tile) -> Result<()> {
        let dims = self.cache.runtime().dims;
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let u = tile.u;

        if self.pool.size() == 0 {
            // hot path: no staging, no allocation — operate on the store
            for gi in 0..g {
                let m = gi / b;
                let y = streams.block(gi, tile.src_l - 1, tile.src_r);
                let out = pending.block_mut(gi, tile.dst_l - 1, tile.dst_r);
                tile_conv_direct_into(y, self.cache.seg(m, u), out, d);
            }
            return Ok(());
        }

        // parallel across groups (Algorithm 3): disjoint output blocks per
        // group; hand each worker a raw view of its own slice. Filter
        // segments are extracted first so the closure captures only Sync
        // data (the RhoCache holds non-Sync PJRT state).
        let segs: Vec<&[f32]> = (0..dims.m).map(|m| self.cache.seg(m, u)).collect();
        let pend_ptr = PendingPtr(pending.data_mut().as_mut_ptr());
        let pend_ptr = &pend_ptr; // borrow whole wrapper (edition-2021 disjoint capture)
        let l = streams.shape()[1];
        self.pool.scoped_for(g, |gi| {
            let y = streams.block(gi, tile.src_l - 1, tile.src_r);
            // SAFETY: blocks [gi, dst_l-1..dst_r] are disjoint across gi.
            let out = unsafe {
                std::slice::from_raw_parts_mut(
                    (pend_ptr.0).add((gi * l + tile.dst_l - 1) * d),
                    u * d,
                )
            };
            tile_conv_direct_into(y, segs[gi / b], out, d);
        });
        Ok(())
    }
}

/// Send-able wrapper for the disjoint-slice pattern above.
struct PendingPtr(*mut f32);
unsafe impl Send for PendingPtr {}
unsafe impl Sync for PendingPtr {}

#[cfg(test)]
mod tests {
    // covered by tau::tests_common (integration over real artifacts) and
    // the pure-kernel tests in fft::conv; the unsafe parallel path is
    // additionally exercised by tests_common::parallel_matches_serial.
}
