//! Native direct-tile τ — the FlashConv1D analogue: quadratic FLOPs but
//! zero dispatch overhead and fully streaming memory access, which makes
//! it the small-U winner on the Hybrid's Pareto frontier (Fig 3a).

use anyhow::Result;

use super::{RhoCache, TauImpl, TauKind};
use crate::fft::tile_conv_direct_into;
use crate::tiling::Tile;
use crate::util::tensor::CellTensor;
use crate::util::threadpool::ThreadPool;

pub struct RustDirect<'c, 'rt> {
    cache: &'c RhoCache<'rt>,
    pool: ThreadPool,
}

impl<'c, 'rt> RustDirect<'c, 'rt> {
    pub fn new(cache: &'c RhoCache<'rt>, threads: usize) -> Self {
        RustDirect { cache, pool: ThreadPool::new(threads) }
    }
}

impl TauImpl for RustDirect<'_, '_> {
    fn kind(&self) -> TauKind {
        TauKind::RustDirect
    }

    fn apply(&mut self, streams: &CellTensor, pending: &CellTensor, tile: Tile) -> Result<()> {
        let dims = self.cache.runtime().dims;
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let u = tile.u;

        if self.pool.size() == 0 {
            // hot path: no staging, no allocation — operate on the store
            for gi in 0..g {
                let m = gi / b;
                let y = streams.block(gi, tile.src_l - 1, tile.src_r);
                // SAFETY: synchronous apply under the deadline contract —
                // the tile's dst rows are exclusively this caller's
                let out = unsafe { pending.block_mut(gi, tile.dst_l - 1, tile.dst_r) };
                tile_conv_direct_into(y, self.cache.seg(m, u), out, d);
            }
            return Ok(());
        }

        // parallel across groups (Algorithm 3): disjoint output blocks per
        // group, each worker deriving a &mut over its own group's dst
        // block through the Sync cell plane. Filter segments are extracted
        // first so the closure captures only Sync data (the RhoCache holds
        // non-Sync PJRT state).
        let segs: Vec<&[f32]> = (0..dims.m).map(|m| self.cache.seg(m, u)).collect();
        self.pool.scoped_for(g, |gi| {
            let y = streams.block(gi, tile.src_l - 1, tile.src_r);
            // SAFETY: blocks [gi, dst_l-1..dst_r] are disjoint across gi,
            // and the tile's rows are this apply call's per the contract.
            let out = unsafe { pending.block_mut(gi, tile.dst_l - 1, tile.dst_r) };
            tile_conv_direct_into(y, segs[gi / b], out, d);
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // covered by tau::tests_common (integration over real artifacts) and
    // the pure-kernel tests in fft::conv; the unsafe parallel path is
    // additionally exercised by tests_common::parallel_matches_serial.
}
