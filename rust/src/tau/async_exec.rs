//! Deadline-fenced asynchronous τ execution — the paper's across-layer
//! parallelism claim ("the tiling allows for almost complete
//! parallelization ... of the position-mixing part") applied to the *time*
//! axis: a gray tile at iteration `i` produces `z[i+1..i+U]`, but only
//! `z[i+1]` is consumed at the very next step — everything else has a
//! deadline several red steps in the future. [`AsyncTau`] exploits that
//! slack by running tiles on a dedicated pool worker while the engine
//! thread continues with sampling, token bookkeeping, metrics, and the
//! next step's host→device uploads, fencing only immediately before the
//! pending column is gathered (FutureFill-style deadline scheduling;
//! Laughing Hyena's observation that per-token critical path, not FLOPs,
//! governs serving latency is exactly what this buys back).
//!
//! ## Execution model
//!
//! * One in-flight queue on a **single-worker** [`ThreadPool`]: execution
//!   order == submission order, so two tiles with overlapping destination
//!   ranges (e.g. a split remainder of tile `i` and tile `i+1`, which both
//!   accumulate into `z[i+2]`) can never race each other — ordering, not
//!   locking, serializes the `+=`s in exactly the sync path's order.
//! * [`AsyncTau::fence`] joins every in-flight tile whose destination
//!   covers the named column; tiles aimed entirely at later columns keep
//!   running. Completed tiles are retired opportunistically so the queue
//!   never grows beyond the few truly outstanding jobs.
//! * **Split tiles**: for `U >= split_min_u` the urgent first column
//!   `z[i+1]` is computed *synchronously at submission* by a direct
//!   kernel (O(U·D) per group — cheap), and the relaxed remainder
//!   `z[i+2..i+U]` is submitted with its natural deadline of step `i+2`.
//!   The expensive order-2U FFT then overlaps the *entire* next red-step
//!   PJRT call instead of stalling the very next fence. The remainder's
//!   FFT computes the full cyclic convolution but accumulates only rows
//!   `>= 1`, so contributions land exactly once; the urgent column's
//!   value differs from the unsplit path only by direct-vs-FFT rounding
//!   (see DESIGN.md §Pipelining for the accumulation-order caveat —
//!   equivalence is bit-exact with splitting off, tolerance-bounded with
//!   it on).
//! * **Lane recycling (continuous admission)**: `Session::admit` clears
//!   one batch lane's store rows while the batch keeps running. Every
//!   submitted tile's destination covers *all* `G = M·B` groups — there
//!   is no per-lane tile — so a tile in flight at admission time always
//!   covers the recycled lane: it would read the predecessor's streams
//!   rows after the reset, or re-deposit predecessor pending sums over
//!   the cleared rows. Admission therefore drains with [`AsyncTau::
//!   fence_all`] (the "fence tiles whose dst covers the recycled lane"
//!   rule degenerates to fence-everything), and `Store::reset_lane`'s
//!   quiet-row assertion converts a missed admission fence into a
//!   deterministic panic rather than cross-request activation leakage.
//! * Wrap safety (Appendix D half store): a split remainder outlives the
//!   next fence, so its source rows must not be recycled underneath it.
//!   Splitting is therefore disabled when `2U > rows` — only the single
//!   largest tile in a wrapped store, where source row `row(1)` would be
//!   overwritten by the red step writing `row(rows+1)` — and the
//!   [`RowReadiness`] tracker attached by the session turns any future
//!   violation of this analysis into a deterministic panic.
//!
//! ## Why only native impls
//!
//! The job closures must be `Send + 'static`, so they capture `Arc`'d
//! filter state (rfft plans, half-spectrum planes, filter-prefix
//! snapshots) plus raw tensor pointers — never `&RhoCache` (PJRT handles
//! are not `Send`, and the cache's lazy maps are not `Sync`). The
//! PJRT-backed kinds — and `Hybrid`, which may dispatch to them — stay on
//! the engine thread via the trait's synchronous defaults.

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{FenceStats, RhoCache, TauImpl, TauKind};
use crate::engine::store::RowReadiness;
use crate::fft::{tile_conv_rfft_into, RfftPlan, TileScratch};
use crate::tau::rho_cache::Spectra;
use crate::tiling::Tile;
use crate::util::tensor::Tensor;
use crate::util::threadpool::{JobHandle, ThreadPool};

thread_local! {
    /// Per-worker scratch: FFT planes plus a remainder accumulator. The
    /// executor worker is persistent (util::threadpool), so after the
    /// first tile the token loop stays allocation-free off-thread too.
    static ASYNC_SCRATCH: RefCell<(TileScratch, Vec<f32>)> =
        RefCell::new((TileScratch::default(), Vec::new()));
}

/// Raw-pointer wrappers for the detached jobs. SAFETY: sendable only
/// under the deadline contract — the session fences before any
/// conflicting access and [`AsyncTau`]'s `Drop` drains the queue, so no
/// dereference outlives the store or races a live borrow (all concurrent
/// accesses are to disjoint `[row][D]` regions; see module docs).
#[derive(Clone, Copy)]
struct ConstPtr(*const f32);
unsafe impl Send for ConstPtr {}
unsafe impl Sync for ConstPtr {}

#[derive(Clone, Copy)]
struct MutPtr(*mut f32);
unsafe impl Send for MutPtr {}
unsafe impl Sync for MutPtr {}

/// Worker-side tile kernel: the `Send + Sync` snapshot of everything a
/// detached tile needs from the rho cache.
#[derive(Clone)]
enum Kernel {
    /// Native rfft pipeline (mirrors `RustFft::apply`'s inline loop).
    Fft { plan: Arc<RfftPlan>, spectra: Arc<Spectra> },
    /// Native direct tile (mirrors `RustDirect::apply`'s inline loop)
    /// over a `[M, 2U, D]` filter-prefix snapshot.
    Direct { seg: Arc<Vec<f32>> },
}

struct InFlight {
    handle: JobHandle,
    /// Destination range in submitted-tile row coordinates (1-indexed,
    /// inclusive — `fence(col)` joins jobs with `dst_l <= col <= dst_r`).
    dst_l: usize,
    dst_r: usize,
}

/// Asynchronous executor wrapping a native synchronous τ implementation.
pub struct AsyncTau<'c, 'rt> {
    cache: &'c RhoCache<'rt>,
    /// The wrapped impl: provides `kind`/`tile_flops` and the synchronous
    /// `apply` fallback; its own worker pool is idle under async
    /// execution (tiles run group-sequential on the executor worker).
    inner: Box<dyn TauImpl + 'c>,
    /// Single worker — FIFO execution is the write-ordering guarantee.
    pool: ThreadPool,
    inflight: VecDeque<InFlight>,
    readiness: Option<Arc<RowReadiness>>,
    split_min_u: usize,
    /// Worker-side compute ns, drained by `take_worker_ns` (hidden-mixer
    /// accounting).
    worker_ns: Arc<AtomicU64>,
    /// Per-U `[M, 2U, D]` filter-prefix snapshots for worker-side direct
    /// kernels (the cache's own segments borrow `'c`, jobs need owned).
    segs: HashMap<usize, Arc<Vec<f32>>>,
}

impl<'c, 'rt> AsyncTau<'c, 'rt> {
    /// `split_min_u == 0` disables tile splitting (async whole-tile
    /// execution only — bit-identical to the sync path).
    pub fn new(
        cache: &'c RhoCache<'rt>,
        inner: Box<dyn TauImpl + 'c>,
        split_min_u: usize,
    ) -> AsyncTau<'c, 'rt> {
        debug_assert!(
            matches!(inner.kind(), TauKind::RustDirect | TauKind::RustFft),
            "AsyncTau wraps native impls only (PJRT handles are not Send)"
        );
        AsyncTau {
            cache,
            inner,
            pool: ThreadPool::new(1),
            inflight: VecDeque::new(),
            readiness: None,
            split_min_u,
            worker_ns: Arc::new(AtomicU64::new(0)),
            segs: HashMap::new(),
        }
    }

    /// Tiles currently submitted but not yet retired by a fence.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    fn seg_snapshot(&mut self, u: usize) -> Arc<Vec<f32>> {
        if let Some(s) = self.segs.get(&u) {
            return s.clone();
        }
        let dims = self.cache.runtime().dims;
        let mut seg = Vec::with_capacity(dims.m * 2 * u * dims.d);
        for m in 0..dims.m {
            seg.extend_from_slice(self.cache.seg(m, u));
        }
        let s = Arc::new(seg);
        self.segs.insert(u, s.clone());
        s
    }

    fn kernel_for(&mut self, u: usize) -> Kernel {
        match self.inner.kind() {
            TauKind::RustFft => Kernel::Fft {
                plan: self.cache.plan(u),
                spectra: self.cache.spectra(u),
            },
            TauKind::RustDirect => Kernel::Direct { seg: self.seg_snapshot(u) },
            _ => unreachable!("AsyncTau wraps native impls only"),
        }
    }

    fn retire(job: InFlight) -> Result<()> {
        job.handle
            .join()
            .map_err(|e| anyhow!("async tau tile [{}, {}]: {e}", job.dst_l, job.dst_r))
    }

    /// Join in-flight jobs selected by `pred`; retire any job observed
    /// already complete along the way. A join error (panicked tile) is
    /// reported *after* the sweep completes, so jobs that are still in
    /// flight are never dropped from tracking — later fences and `Drop`
    /// can still drain them.
    fn fence_where(&mut self, pred: impl Fn(&InFlight) -> bool) -> Result<FenceStats> {
        if self.inflight.is_empty() {
            return Ok(FenceStats::default());
        }
        let t0 = Instant::now();
        let mut waited = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        let mut remaining = VecDeque::with_capacity(self.inflight.len());
        while let Some(job) = self.inflight.pop_front() {
            if pred(&job) {
                if !job.handle.is_done() {
                    waited += 1;
                }
                if let Err(e) = Self::retire(job) {
                    first_err.get_or_insert(e);
                }
            } else if job.handle.is_done() {
                if let Err(e) = Self::retire(job) {
                    first_err.get_or_insert(e);
                }
            } else {
                remaining.push_back(job);
            }
        }
        self.inflight = remaining;
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(FenceStats {
            wait_ns: if waited > 0 { t0.elapsed().as_nanos() as u64 } else { 0 },
            jobs_waited: waited,
        })
    }

    /// Urgent split-tile column: accumulate the tile's first output row
    /// `z[dst_l]` for every group with a direct kernel (`k = 0` slice of
    /// `fft::tile_conv_direct_into`), synchronously on the engine thread.
    fn urgent_first_col(&self, streams: &Tensor, pending: &mut Tensor, tile: Tile) {
        let dims = self.cache.runtime().dims;
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let u = tile.u;
        for gi in 0..g {
            let rho = self.cache.seg(gi / b, u);
            let y = streams.block(gi, tile.src_l - 1, tile.src_r);
            let out = pending.at2_mut(gi, tile.dst_l - 1);
            for j in 0..u {
                let r = &rho[(u - j) * d..(u - j + 1) * d];
                let yj = &y[j * d..(j + 1) * d];
                for t in 0..d {
                    out[t] += yj[t] * r[t];
                }
            }
        }
    }

    /// Enqueue rows `k0..U` of `tile` onto the executor worker.
    fn enqueue(
        &mut self,
        streams: &Tensor,
        pending: &mut Tensor,
        tile: Tile,
        k0: usize,
    ) {
        let dims = self.cache.runtime().dims;
        let (g, d, b) = (dims.g, dims.d, dims.b);
        let l = streams.shape()[1];
        let kernel = self.kernel_for(tile.u);
        let dst_l = tile.dst_l + k0;
        let dst_r = tile.dst_r;

        if let Some(r) = &self.readiness {
            r.begin_write(dst_l - 1..dst_r);
        }
        let readiness = self.readiness.clone();
        let worker_ns = self.worker_ns.clone();
        // SAFETY (lifetime erasure): the pointers outlive the job because
        // every code path that drops or conflictingly touches the store
        // fences first — `fence(col)` before each gather, `fence_all` in
        // `apply`/`Session::finish`, and `Drop` below drains the queue
        // unconditionally. Disjointness: the job writes only pending rows
        // [dst_l-1+k0, dst_r) and reads only streams rows
        // [src_l-1, src_r); the fence discipline (DESIGN.md §Pipelining)
        // keeps all concurrent engine-thread accesses on other rows.
        // Unsplit tiles (the default) are additionally clean under the
        // Stacked Borrows model: the engine thread creates no store
        // borrow between submission and the joining fence. Split
        // remainders outlive the next step's gather/streams-store, whose
        // safe reborrows of the same allocations technically invalidate
        // these raw tags even though the rows are disjoint — the same
        // model-gray disjoint-rows pattern as the scoped_for kernels; the
        // model-clean fix (UnsafeCell-backed store) is a ROADMAP item.
        let sp = ConstPtr(streams.data().as_ptr());
        let pp = MutPtr(pending.data_mut().as_mut_ptr());
        let handle = self.pool.submit(Box::new(move || {
            let t0 = Instant::now();
            run_tile(&kernel, sp, pp, l, g, b, d, tile, k0);
            if let Some(r) = &readiness {
                r.end_write(dst_l - 1..dst_r);
            }
            worker_ns.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
        }));
        self.inflight.push_back(InFlight { handle, dst_l, dst_r });
    }
}

impl TauImpl for AsyncTau<'_, '_> {
    fn kind(&self) -> TauKind {
        self.inner.kind()
    }

    /// Synchronous fallback: drain in-flight work, then run the wrapped
    /// impl directly (callers that mix `apply` and `submit` stay safe).
    fn apply(&mut self, streams: &Tensor, pending: &mut Tensor, tile: Tile) -> Result<()> {
        self.fence_all()?;
        self.inner.apply(streams, pending, tile)
    }

    fn tile_flops(&self, u: usize, g: usize, d: usize) -> u64 {
        self.inner.tile_flops(u, g, d)
    }

    fn submit(&mut self, streams: &Tensor, pending: &mut Tensor, tile: Tile) -> Result<()> {
        let rows = streams.shape()[1];
        // Split when the tile is big enough to be worth it and the store
        // cannot wrap its source rows while the remainder is in flight
        // (2U <= rows; see module docs — only excludes the largest tile
        // of an Appendix D half store).
        let split = self.split_min_u > 0
            && tile.u >= self.split_min_u
            && tile.u >= 2
            && 2 * tile.u <= rows;
        if split {
            // the urgent column is written on the engine thread; the FIFO
            // deadline discipline guarantees no in-flight job still covers
            // it (any such job covered col dst_l-1's gather fence, or had
            // u = 1 and never split) — enforce that analysis
            if let Some(r) = &self.readiness {
                r.assert_quiet(tile.dst_l - 1);
            }
            self.urgent_first_col(streams, pending, tile);
            self.enqueue(streams, pending, tile, 1);
        } else {
            self.enqueue(streams, pending, tile, 0);
        }
        Ok(())
    }

    fn fence(&mut self, col: usize) -> Result<FenceStats> {
        self.fence_where(|j| j.dst_l <= col && col <= j.dst_r)
    }

    fn fence_all(&mut self) -> Result<FenceStats> {
        self.fence_where(|_| true)
    }

    fn take_worker_ns(&mut self) -> u64 {
        self.worker_ns.swap(0, Ordering::Relaxed)
    }

    fn attach_readiness(&mut self, readiness: Arc<RowReadiness>) {
        self.readiness = Some(readiness);
    }
}

impl Drop for AsyncTau<'_, '_> {
    /// Drain the queue so no job outlives the borrowed store. Join
    /// errors are swallowed: a panicked tile already surfaced (or will)
    /// via the owning session's fence, and `Drop` must not double-panic.
    fn drop(&mut self) {
        while let Some(job) = self.inflight.pop_front() {
            let _ = job.handle.join();
        }
    }
}

/// The detached tile body: accumulate rows `k0..U` of the tile for every
/// group, group-sequential (identical per-group arithmetic order to the
/// wrapped impl's inline loop, so unsplit async output is bit-identical
/// to sync output).
#[allow(clippy::too_many_arguments)]
fn run_tile(
    kernel: &Kernel,
    streams: ConstPtr,
    pending: MutPtr,
    l: usize,
    g: usize,
    b: usize,
    d: usize,
    tile: Tile,
    k0: usize,
) {
    let u = tile.u;
    ASYNC_SCRATCH.with(|cell| {
        let (scratch, acc) = &mut *cell.borrow_mut();
        for gi in 0..g {
            let m = gi / b;
            // SAFETY: per the submission contract — disjoint rows, fenced
            // lifetime (see `AsyncTau::enqueue`). The mutable slice starts
            // at row k0, NOT at the tile's first row: for a split
            // remainder the urgent row dst_l-1 belongs to the engine
            // thread (it may gather or zero-fill it before this job's
            // fence), so the job's &mut must never span it.
            let y = unsafe {
                std::slice::from_raw_parts(streams.0.add((gi * l + tile.src_l - 1) * d), u * d)
            };
            let out = unsafe {
                std::slice::from_raw_parts_mut(
                    pending.0.add((gi * l + tile.dst_l - 1 + k0) * d),
                    (u - k0) * d,
                )
            };
            match kernel {
                Kernel::Fft { plan, spectra } => {
                    let (sre, sim) = spectra.planes(m);
                    if k0 == 0 {
                        tile_conv_rfft_into(plan, y, sre, sim, out, scratch, d);
                    } else {
                        // remainder: full conv into the accumulator, land
                        // only rows >= k0 (row 0 was the urgent column)
                        acc.clear();
                        acc.resize(u * d, 0.0);
                        tile_conv_rfft_into(plan, y, sre, sim, acc, scratch, d);
                        for (o, v) in out.iter_mut().zip(&acc[k0 * d..]) {
                            *o += v;
                        }
                    }
                }
                Kernel::Direct { seg } => {
                    let rho = &seg[m * 2 * u * d..(m + 1) * 2 * u * d];
                    direct_rows(y, rho, out, d, k0);
                }
            }
        }
    });
}

/// Direct tile restricted to output rows `k0..U`. `out_add` holds exactly
/// those rows (`[(U-k0)][d]`, starting at row k0 of the tile) — the
/// `k0 == 0` case is exactly `fft::tile_conv_direct_into`.
fn direct_rows(y: &[f32], rho_seg: &[f32], out_add: &mut [f32], d: usize, k0: usize) {
    let u = y.len() / d;
    debug_assert_eq!(rho_seg.len(), 2 * u * d);
    debug_assert_eq!(out_add.len(), (u - k0) * d);
    for j in 0..u {
        let yj = &y[j * d..(j + 1) * d];
        let rho_base = (u - j) * d;
        for k in k0..u {
            let r = &rho_seg[rho_base + k * d..rho_base + (k + 1) * d];
            let o = &mut out_add[(k - k0) * d..(k - k0 + 1) * d];
            for t in 0..d {
                o[t] += yj[t] * r[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn direct_rows_full_matches_reference_kernel() {
        for (u, d) in [(1usize, 1usize), (4, 3), (16, 8)] {
            let y = rand_vec(u * d, 1);
            let rho = rand_vec(2 * u * d, 2);
            let mut want = vec![0.0f32; u * d];
            crate::fft::tile_conv_direct_into(&y, &rho, &mut want, d);
            let mut got = vec![0.0f32; u * d];
            direct_rows(&y, &rho, &mut got, d, 0);
            assert_eq!(got, want, "u={u} d={d}");
        }
    }

    #[test]
    fn direct_rows_split_covers_each_row_once() {
        // urgent row 0 + remainder rows 1.. must equal the whole tile
        let (u, d) = (8usize, 4usize);
        let y = rand_vec(u * d, 3);
        let rho = rand_vec(2 * u * d, 4);
        let mut want = vec![0.0f32; u * d];
        direct_rows(&y, &rho, &mut want, d, 0);

        let mut got = vec![0.0f32; u * d];
        // row 0 via the urgent-column loop shape
        for j in 0..u {
            let r = &rho[(u - j) * d..(u - j + 1) * d];
            let yj = &y[j * d..(j + 1) * d];
            for t in 0..d {
                got[t] += yj[t] * r[t];
            }
        }
        // remainder slice starts at row 1 (mirrors run_tile's offset view)
        direct_rows(&y, &rho, &mut got[d..], d, 1);
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b);
        }
    }

    // AsyncTau end-to-end behaviour (bit-identical unsplit output,
    // tolerance-bounded split output, fence ordering under churn) is
    // covered against real artifacts in tests/integration_async.rs.
}
