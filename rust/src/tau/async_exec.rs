//! Deadline-fenced asynchronous τ execution — the paper's across-layer
//! parallelism claim ("the tiling allows for almost complete
//! parallelization ... of the position-mixing part") applied to the *time*
//! axis: a gray tile at iteration `i` produces `z[i+1..i+U]`, but only
//! `z[i+1]` is consumed at the very next step — everything else has a
//! deadline several red steps in the future. [`AsyncTau`] exploits that
//! slack by running tiles on pool workers while the engine thread
//! continues with sampling, token bookkeeping, metrics, and the next
//! step's host→device uploads, fencing only immediately before the
//! pending column is gathered (FutureFill-style deadline scheduling;
//! Laughing Hyena's observation that per-token critical path, not FLOPs,
//! governs serving latency is exactly what this buys back).
//!
//! ## Execution model (dependency-tracked, multi-worker)
//!
//! * Jobs go to a [`ThreadPool`] of `mixer_workers` workers. Safety for
//!   the shared `+=` destinations comes from **dependency edges**, not
//!   from global FIFO: at submission, a new job records a happens-before
//!   edge ([`ThreadPool::submit_after`]) on every in-flight job whose
//!   destination row range overlaps its own. Overlapping-dst jobs
//!   therefore run in submission order — exactly the sync path's
//!   accumulation order, which keeps unsplit async output bit-identical
//!   to sync at *any* worker count — while disjoint-dst jobs fan out
//!   across workers and run concurrently. At `mixer_workers = 1` the
//!   dependency queue degenerates to the old FIFO executor.
//! * [`AsyncTau::fence`] joins every in-flight job whose destination
//!   covers the named column; jobs aimed entirely at later columns keep
//!   running. Completed jobs are retired opportunistically so the queue
//!   (and the dependency scan) never grows beyond the few truly
//!   outstanding jobs.
//! * **Staged split tiles**: for `U >= split_min_u` a tile is cut into
//!   *chunks with staged deadlines* instead of one monolithic job. Output
//!   rows `[0,1), [1,2), [2,4), [4,8), …` are direct-kernel chunks whose
//!   deadlines are 1, 2, 3, 5, … red steps out — each chunk's cost
//!   (`O(U·rows·D)` per group) is amortized over the slack before its
//!   own fence, so no single fence ever waits on a whole size-U tile.
//!   Under an FFT inner the doubling prefix stops at
//!   [`STAGED_DIRECT_ROWS`] rows and one order-2U FFT *tail chunk*
//!   covers the rest with ≥ `STAGED_DIRECT_ROWS` red steps of slack
//!   (the tail computes the full cyclic convolution and lands only its
//!   own rows, so contributions arrive exactly once). Chunks of one tile
//!   have pairwise-disjoint destinations — no edges between them — so a
//!   multi-worker pool runs them concurrently; each chunk still takes
//!   edges on older overlapping jobs (e.g. the next tile's whole-job,
//!   which shares destination columns with a larger tile's remainder).
//!   The first chunk `[0,1)` is the urgent column: it rides the same
//!   dependency mechanism instead of being computed synchronously at
//!   submission, so nothing on the engine thread ever writes pending.
//!   Split output differs from sync only by direct-vs-FFT rounding on
//!   the direct-prefix rows (tolerance-bounded; bit-exact with splitting
//!   off — see DESIGN.md §Pipelining).
//! * **Lane recycling (continuous admission)**: `Session::admit` clears
//!   one batch lane's store rows while the batch keeps running. Every
//!   submitted job's destination covers *all* `G = M·B` groups — there
//!   is no per-lane job — so a job in flight at admission time always
//!   covers the recycled lane. Admission therefore drains with
//!   [`AsyncTau::fence_all`], and `Store::reset_lane`'s quiet-row
//!   assertion converts a missed admission fence into a deterministic
//!   panic rather than cross-request activation leakage.
//! * Wrap safety (Appendix D half store): a split chunk outlives the
//!   next fence, so its source rows must not be recycled underneath it.
//!   Splitting is therefore disabled when `2U > rows` — only the single
//!   largest tile in a wrapped store, where source row `row(1)` would be
//!   overwritten by the red step writing `row(rows+1)` — and the
//!   versioned [`RowReadiness`] tracker attached by the session turns
//!   any future violation of this analysis into a deterministic panic.
//!
//! ## Memory model
//!
//! Jobs capture `Arc<CellTensor>` handles to the store planes: writes go
//! through `UnsafeCell`-derived pointers scoped to each job's disjoint
//! row range, so nothing the engine thread does through `&self` borrows
//! of the same planes can invalidate a job's access (the pre-CellTensor
//! executor smuggled raw `Tensor` pointers, which was well-defined only
//! up to a Stacked Borrows technicality on split tiles). The `Arc` also
//! keeps the planes alive under any drop order; the executor's `Drop`
//! still drains the queue so a dying session never leaves detached
//! writers running.
//!
//! ## Why only native impls
//!
//! The job closures must be `Send + 'static`, so they capture `Arc`'d
//! filter state (rfft plans, half-spectrum planes, filter-prefix
//! snapshots) plus `Arc<CellTensor>` planes — never `&RhoCache` (PJRT
//! handles are not `Send`, and the cache's lazy maps are not `Sync`).
//! The PJRT-backed kinds — and `Hybrid`, which may dispatch to them —
//! stay on the engine thread via the trait's synchronous defaults (and
//! `make_session_impl` rejects `mixer_workers > 1` for them outright).

use std::cell::RefCell;
use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{anyhow, Result};

use super::{FenceStats, RhoCache, TauImpl, TauKind};
use crate::engine::store::RowReadiness;
use crate::fft::{tile_conv_rfft_fused_into, RfftPlan, TileScratch};
use crate::tau::rho_cache::Spectra;
use crate::tiling::Tile;
use crate::util::faultpoint;
use crate::util::tensor::CellTensor;
use crate::util::threadpool::{JobHandle, ThreadPool};

/// Row count of the direct-kernel doubling prefix of a split tile under
/// an FFT inner. Rows `[0, STAGED_DIRECT_ROWS)` are cheap direct chunks
/// with per-row-ish deadlines; the FFT tail that covers the rest is
/// first fenced `STAGED_DIRECT_ROWS` red steps after submission, which
/// is the slack that hides it. 16 keeps the prefix cost (`16·U·D` per
/// group) within a small factor of the tail FFT itself.
const STAGED_DIRECT_ROWS: usize = 16;

thread_local! {
    /// Per-worker scratch: FFT planes plus a tail accumulator. The pool
    /// workers are persistent (util::threadpool), so after the first few
    /// tiles the token loop stays allocation-free off-thread too.
    static ASYNC_SCRATCH: RefCell<(TileScratch, Vec<f32>)> =
        RefCell::new((TileScratch::default(), Vec::new()));
}

/// Worker-side tile kernel: the `Send + Sync` snapshot of everything a
/// detached job needs from the rho cache.
#[derive(Clone)]
enum Kernel {
    /// Native rfft pipeline (mirrors `RustFft::apply`'s inline loop).
    Fft { plan: Arc<RfftPlan>, spectra: Arc<Spectra> },
    /// Native direct tile (mirrors `RustDirect::apply`'s inline loop)
    /// over a `[M, 2U, D]` filter-prefix snapshot.
    Direct { seg: Arc<Vec<f32>> },
}

/// Busy-span union clock for the hidden-mixer accounting. N workers can
/// be computing simultaneously; summing their per-job durations would
/// report more "hidden" time than wall time elapsed (double-counting the
/// overlap in the fig3c breakdown). The clock instead accumulates the
/// *union* of the busy intervals: time advances only while at least one
/// job is running, so `take_ns` is bounded by wall time regardless of
/// the worker count, and equals the old per-job sum at one worker.
struct WorkerClock {
    inner: Mutex<ClockInner>,
}

struct ClockInner {
    /// Jobs currently inside an `enter` guard.
    active: usize,
    /// When `active` last rose from 0 (meaningless while `active == 0`).
    since: Instant,
    /// Closed busy spans, drained by `take_ns`.
    total_ns: u64,
}

impl WorkerClock {
    fn new() -> WorkerClock {
        WorkerClock {
            inner: Mutex::new(ClockInner { active: 0, since: Instant::now(), total_ns: 0 }),
        }
    }

    /// Enter a busy span; the guard closes it on drop (unwind-safe, so a
    /// panicking kernel does not wedge the clock open).
    fn enter(&self) -> ClockGuard<'_> {
        let mut c = self.inner.lock().unwrap();
        if c.active == 0 {
            c.since = Instant::now();
        }
        c.active += 1;
        drop(c);
        ClockGuard(self)
    }

    fn exit(&self) {
        let mut c = self.inner.lock().unwrap();
        c.active -= 1;
        if c.active == 0 {
            c.total_ns += c.since.elapsed().as_nanos() as u64;
        }
    }

    /// Drain the accumulated busy time. An open span is folded in up to
    /// now and restarted, so long-running jobs attribute their time to
    /// the step that observed it.
    fn take_ns(&self) -> u64 {
        let mut c = self.inner.lock().unwrap();
        let mut total = c.total_ns;
        c.total_ns = 0;
        if c.active > 0 {
            total += c.since.elapsed().as_nanos() as u64;
            c.since = Instant::now();
        }
        total
    }
}

struct ClockGuard<'a>(&'a WorkerClock);

impl Drop for ClockGuard<'_> {
    fn drop(&mut self) {
        self.0.exit();
    }
}

/// Balances a `begin_write` bracket even when the tile kernel panics:
/// the worker's `catch_unwind` drops this guard during unwind, so
/// `RowReadiness` never sticks at `scheduled > completed`. The panic
/// then surfaces *only* as `JobError::Panicked` at the next fence — a
/// lane-level failure the supervisor can absorb — instead of poisoning
/// every later `assert_quiet` (reset/suspend/teardown) into a re-panic.
struct EndWriteGuard {
    readiness: Option<Arc<RowReadiness>>,
    rows: std::ops::Range<usize>,
}

impl Drop for EndWriteGuard {
    fn drop(&mut self) {
        if let Some(r) = &self.readiness {
            r.end_write(self.rows.clone());
        }
    }
}

struct InFlight {
    handle: JobHandle,
    /// Destination range in submitted-tile row coordinates (1-indexed,
    /// inclusive — `fence(col)` joins jobs with `dst_l <= col <= dst_r`,
    /// and new jobs take dependency edges on overlapping ranges).
    dst_l: usize,
    dst_r: usize,
}

/// One staged chunk of a split tile: output rows `[k0, k1)` of the tile,
/// computed by the direct kernel or (tail only) the order-2U FFT.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct Chunk {
    k0: usize,
    k1: usize,
    fft: bool,
}

/// Staged-deadline chunk schedule for a split size-`u` tile. Row ranges
/// are disjoint and cover `[0, u)`: a doubling direct prefix
/// `[0,1), [1,2), [2,4), …` whose chunk deadlines amortize over the red
/// steps before each chunk's own fence, and — iff `fft_tail` and the
/// prefix stops short of `u` — one FFT chunk for the remaining rows.
fn chunk_plan(u: usize, fft_tail: bool) -> Vec<Chunk> {
    let c = if fft_tail { STAGED_DIRECT_ROWS.min(u) } else { u };
    let mut plan = Vec::new();
    let mut k0 = 0usize;
    while k0 < c {
        let k1 = if k0 == 0 { 1 } else { (2 * k0).min(c) };
        plan.push(Chunk { k0, k1, fft: false });
        k0 = k1;
    }
    if c < u {
        plan.push(Chunk { k0: c, k1: u, fft: true });
    }
    plan
}

/// Asynchronous executor wrapping a native synchronous τ implementation.
pub struct AsyncTau<'c, 'rt> {
    cache: &'c RhoCache<'rt>,
    /// The wrapped impl: provides `kind`/`tile_flops` and the synchronous
    /// `apply` fallback; its own worker pool is idle under async
    /// execution (tiles run group-sequential inside each job).
    inner: Box<dyn TauImpl + 'c>,
    /// `mixer_workers` workers; the dependency edges recorded at submit
    /// are the write-ordering guarantee (see module docs).
    pool: ThreadPool,
    inflight: VecDeque<InFlight>,
    readiness: Option<Arc<RowReadiness>>,
    split_min_u: usize,
    /// Busy-span union of all workers, drained by `take_worker_ns`.
    clock: Arc<WorkerClock>,
    /// Per-U `[M, 2U, D]` filter-prefix snapshots for worker-side direct
    /// kernels (the cache's own segments borrow `'c`, jobs need owned).
    segs: HashMap<usize, Arc<Vec<f32>>>,
}

impl<'c, 'rt> AsyncTau<'c, 'rt> {
    /// `split_min_u == 0` disables tile splitting (async whole-tile
    /// execution only — bit-identical to the sync path at any worker
    /// count). `workers` is clamped to ≥ 1.
    pub fn new(
        cache: &'c RhoCache<'rt>,
        inner: Box<dyn TauImpl + 'c>,
        split_min_u: usize,
        workers: usize,
    ) -> AsyncTau<'c, 'rt> {
        debug_assert!(
            matches!(inner.kind(), TauKind::RustDirect | TauKind::RustFft),
            "AsyncTau wraps native impls only (PJRT handles are not Send)"
        );
        AsyncTau {
            cache,
            inner,
            pool: ThreadPool::new(workers.max(1)),
            inflight: VecDeque::new(),
            readiness: None,
            split_min_u,
            clock: Arc::new(WorkerClock::new()),
            segs: HashMap::new(),
        }
    }

    /// Jobs currently submitted but not yet retired by a fence.
    pub fn in_flight(&self) -> usize {
        self.inflight.len()
    }

    fn seg_snapshot(&mut self, u: usize) -> Arc<Vec<f32>> {
        if let Some(s) = self.segs.get(&u) {
            return s.clone();
        }
        let dims = self.cache.runtime().dims;
        let mut seg = Vec::with_capacity(dims.m * 2 * u * dims.d);
        for m in 0..dims.m {
            seg.extend_from_slice(self.cache.seg(m, u));
        }
        let s = Arc::new(seg);
        self.segs.insert(u, s.clone());
        s
    }

    fn retire(job: InFlight) -> Result<()> {
        job.handle.join().map_err(|e| match job.handle.panic_message() {
            Some(msg) => anyhow!("async tau tile [{}, {}]: {e}: {msg}", job.dst_l, job.dst_r),
            None => anyhow!("async tau tile [{}, {}]: {e}", job.dst_l, job.dst_r),
        })
    }

    /// Join in-flight jobs selected by `pred`; retire any job observed
    /// already complete along the way. A join error (panicked tile) is
    /// reported *after* the sweep completes, so jobs that are still in
    /// flight are never dropped from tracking — later fences and `Drop`
    /// can still drain them.
    fn fence_where(&mut self, pred: impl Fn(&InFlight) -> bool) -> Result<FenceStats> {
        if self.inflight.is_empty() {
            return Ok(FenceStats::default());
        }
        let t0 = Instant::now();
        let mut waited = 0usize;
        let mut first_err: Option<anyhow::Error> = None;
        let mut remaining = VecDeque::with_capacity(self.inflight.len());
        while let Some(job) = self.inflight.pop_front() {
            if pred(&job) {
                if !job.handle.is_done() {
                    waited += 1;
                }
                if let Err(e) = Self::retire(job) {
                    first_err.get_or_insert(e);
                }
            } else if job.handle.is_done() {
                if let Err(e) = Self::retire(job) {
                    first_err.get_or_insert(e);
                }
            } else {
                remaining.push_back(job);
            }
        }
        self.inflight = remaining;
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(FenceStats {
            wait_ns: if waited > 0 { t0.elapsed().as_nanos() as u64 } else { 0 },
            jobs_waited: waited,
        })
    }

    /// Enqueue output rows `[k0, k1)` of `tile` as one pool job, with
    /// happens-before edges on every in-flight job whose destination
    /// rows overlap this chunk's.
    fn enqueue(
        &mut self,
        streams: &Arc<CellTensor>,
        pending: &Arc<CellTensor>,
        tile: Tile,
        chunk: Chunk,
    ) {
        let dims = self.cache.runtime().dims;
        let (d, b) = (dims.d, dims.b);
        let kernel = if chunk.fft {
            Kernel::Fft { plan: self.cache.plan(tile.u), spectra: self.cache.spectra(tile.u) }
        } else {
            Kernel::Direct { seg: self.seg_snapshot(tile.u) }
        };
        let (k0, k1) = (chunk.k0, chunk.k1);
        let dst_l = tile.dst_l + k0;
        let dst_r = tile.dst_l + k1 - 1;

        if let Some(r) = &self.readiness {
            r.begin_write(dst_l - 1..dst_r);
        }
        let readiness = self.readiness.clone();
        let clock = self.clock.clone();
        let streams = streams.clone();
        let pending = pending.clone();
        let job = Box::new(move || {
            let _busy = clock.enter();
            // Drop order: the guard ends the readiness window whether the
            // kernel returns or unwinds (see `EndWriteGuard`).
            let _end = EndWriteGuard { readiness, rows: dst_l - 1..dst_r };
            // Chaos handles for the worker-side tile path. `check` only
            // errs for `fail` actions; on this no-Result path that
            // degrades to a panic at the same site, which is the intent.
            faultpoint::check("tile_delay").expect("fault injection: tile_delay");
            faultpoint::check("tau_tile").expect("fault injection: tau_tile");
            run_tile(&kernel, &streams, &pending, b, d, tile, k0, k1);
        });
        // Dependency edges: in-flight jobs whose (1-indexed, inclusive)
        // destination ranges intersect ours wrote or will write some of
        // our rows — execution must respect submission order there to
        // reproduce the sync path's `+=` order. Ranges are compared in
        // store-row coordinates as submitted, so the Appendix D wrap
        // (two absolute positions aliasing one store row) is covered.
        // Already-done jobs need no edge; their writes are visible via
        // the pool's status handshake.
        let deps: Vec<&JobHandle> = self
            .inflight
            .iter()
            .filter(|j| j.dst_l <= dst_r && dst_l <= j.dst_r && !j.handle.is_done())
            .map(|j| &j.handle)
            .collect();
        let handle = self.pool.submit_after(&deps, job);
        self.inflight.push_back(InFlight { handle, dst_l, dst_r });
    }
}

impl TauImpl for AsyncTau<'_, '_> {
    fn kind(&self) -> TauKind {
        self.inner.kind()
    }

    /// Synchronous fallback: drain in-flight work, then run the wrapped
    /// impl directly (callers that mix `apply` and `submit` stay safe).
    fn apply(&mut self, streams: &CellTensor, pending: &CellTensor, tile: Tile) -> Result<()> {
        self.fence_all()?;
        self.inner.apply(streams, pending, tile)
    }

    fn tile_flops(&self, u: usize, g: usize, d: usize) -> u64 {
        self.inner.tile_flops(u, g, d)
    }

    fn submit(
        &mut self,
        streams: &Arc<CellTensor>,
        pending: &Arc<CellTensor>,
        tile: Tile,
    ) -> Result<()> {
        // opportunistically retire completed jobs so the in-flight list
        // (and with it every dependency scan) stays a few entries long
        self.fence_where(|_| false)?;
        let rows = streams.shape()[1];
        // Split when the tile is big enough to be worth it and the store
        // cannot wrap its source rows while a chunk is in flight
        // (2U <= rows; see module docs — only excludes the largest tile
        // of an Appendix D half store).
        let split = self.split_min_u > 0
            && tile.u >= self.split_min_u
            && tile.u >= 2
            && 2 * tile.u <= rows;
        if split {
            let fft_tail = matches!(self.inner.kind(), TauKind::RustFft);
            for chunk in chunk_plan(tile.u, fft_tail) {
                self.enqueue(streams, pending, tile, chunk);
            }
        } else {
            let fft = matches!(self.inner.kind(), TauKind::RustFft);
            self.enqueue(streams, pending, tile, Chunk { k0: 0, k1: tile.u, fft });
        }
        Ok(())
    }

    fn fence(&mut self, col: usize) -> Result<FenceStats> {
        self.fence_where(|j| j.dst_l <= col && col <= j.dst_r)
    }

    fn fence_all(&mut self) -> Result<FenceStats> {
        self.fence_where(|_| true)
    }

    fn take_worker_ns(&mut self) -> u64 {
        self.clock.take_ns()
    }

    fn attach_readiness(&mut self, readiness: Arc<RowReadiness>) {
        self.readiness = Some(readiness);
    }
}

impl Drop for AsyncTau<'_, '_> {
    /// Drain the queue so no detached writer outlives the session's view
    /// of the store (the `Arc`'d planes make a straggler memory-safe,
    /// but a job landing after e.g. `reset_lane` would still be a logic
    /// bug — drain keeps the semantics airtight under any drop order).
    /// Join errors are swallowed: a panicked tile already surfaced (or
    /// will) via the owning session's fence, and `Drop` must not
    /// double-panic.
    fn drop(&mut self) {
        while let Some(job) = self.inflight.pop_front() {
            let _ = job.handle.join();
        }
    }
}

/// The detached job body: accumulate output rows `[k0, k1)` of the tile
/// for every group, group-sequential (identical per-group arithmetic
/// order to the wrapped impl's inline loop, so unsplit async output is
/// bit-identical to sync output).
#[allow(clippy::too_many_arguments)]
fn run_tile(
    kernel: &Kernel,
    streams: &CellTensor,
    pending: &CellTensor,
    b: usize,
    d: usize,
    tile: Tile,
    k0: usize,
    k1: usize,
) {
    let g = streams.shape()[0];
    let u = tile.u;
    ASYNC_SCRATCH.with(|cell| {
        let (scratch, acc) = &mut *cell.borrow_mut();
        for gi in 0..g {
            let m = gi / b;
            let y = streams.block(gi, tile.src_l - 1, tile.src_r);
            // SAFETY: this job owns pending rows [dst_l-1+k0, dst_l-1+k1)
            // exclusively — chunks of one tile are disjoint, overlapping
            // older jobs are ordered before us by dependency edges, and
            // the engine thread fences before touching any of these rows
            // (begin_write/end_write brackets the window). The slice
            // covers exactly our rows, never the neighbours'.
            let out = unsafe { pending.block_mut(gi, tile.dst_l - 1 + k0, tile.dst_l - 1 + k1) };
            match kernel {
                Kernel::Fft { plan, spectra } => {
                    let spec = spectra.blocked(m);
                    if k0 == 0 && k1 == u {
                        tile_conv_rfft_fused_into(plan, y, spec, out, scratch, d);
                    } else {
                        // tail chunk: full cyclic conv into the
                        // accumulator, land only rows [k0, k1) (earlier
                        // rows belong to the direct-prefix chunks)
                        acc.clear();
                        acc.resize(u * d, 0.0);
                        tile_conv_rfft_fused_into(plan, y, spec, acc, scratch, d);
                        for (o, v) in out.iter_mut().zip(&acc[k0 * d..k1 * d]) {
                            *o += v;
                        }
                    }
                }
                Kernel::Direct { seg } => {
                    let rho = &seg[m * 2 * u * d..(m + 1) * 2 * u * d];
                    direct_rows(y, rho, out, d, k0, k1);
                }
            }
        }
    });
}

/// Direct tile restricted to output rows `[k0, k1)`. `out_add` holds
/// exactly those rows (`[(k1-k0)][d]`, starting at row k0 of the tile) —
/// the `(0, U)` case is exactly `fft::tile_conv_direct_into`.
fn direct_rows(y: &[f32], rho_seg: &[f32], out_add: &mut [f32], d: usize, k0: usize, k1: usize) {
    let u = y.len() / d;
    debug_assert_eq!(rho_seg.len(), 2 * u * d);
    debug_assert_eq!(out_add.len(), (k1 - k0) * d);
    for j in 0..u {
        let yj = &y[j * d..(j + 1) * d];
        let rho_base = (u - j) * d;
        for k in k0..k1 {
            let r = &rho_seg[rho_base + k * d..rho_base + (k + 1) * d];
            let o = &mut out_add[(k - k0) * d..(k - k0 + 1) * d];
            for t in 0..d {
                o[t] += yj[t] * r[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn direct_rows_full_matches_reference_kernel() {
        for (u, d) in [(1usize, 1usize), (4, 3), (16, 8)] {
            let y = rand_vec(u * d, 1);
            let rho = rand_vec(2 * u * d, 2);
            let mut want = vec![0.0f32; u * d];
            crate::fft::tile_conv_direct_into(&y, &rho, &mut want, d);
            let mut got = vec![0.0f32; u * d];
            direct_rows(&y, &rho, &mut got, d, 0, u);
            assert_eq!(got, want, "u={u} d={d}");
        }
    }

    #[test]
    fn direct_rows_chunks_cover_each_row_once() {
        // any disjoint chunking of [0, u) must reproduce the whole tile
        let (u, d) = (8usize, 4usize);
        let y = rand_vec(u * d, 3);
        let rho = rand_vec(2 * u * d, 4);
        let mut want = vec![0.0f32; u * d];
        direct_rows(&y, &rho, &mut want, d, 0, u);

        let mut got = vec![0.0f32; u * d];
        for Chunk { k0, k1, .. } in chunk_plan(u, false) {
            direct_rows(&y, &rho, &mut got[k0 * d..k1 * d], d, k0, k1);
        }
        for (a, b) in got.iter().zip(&want) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn chunk_plan_is_disjoint_doubling_cover() {
        for u in [2usize, 4, 16, 64, 1024] {
            for fft_tail in [false, true] {
                let plan = chunk_plan(u, fft_tail);
                // contiguous, disjoint, covering [0, u)
                assert_eq!(plan[0].k0, 0);
                assert_eq!(plan.last().unwrap().k1, u);
                for w in plan.windows(2) {
                    assert_eq!(w[0].k1, w[1].k0, "u={u}");
                    assert!(w[0].k1 > w[0].k0);
                }
                if fft_tail && u > STAGED_DIRECT_ROWS {
                    let tail = plan.last().unwrap();
                    assert!(tail.fft);
                    assert_eq!(tail.k0, STAGED_DIRECT_ROWS);
                    assert!(plan[..plan.len() - 1].iter().all(|c| !c.fft));
                } else {
                    assert!(plan.iter().all(|c| !c.fft), "u={u} stays all-direct");
                }
                // the direct prefix doubles: each chunk is at most as
                // large as all rows before it (deadline ≥ cost shape)
                for c in &plan {
                    if !c.fft {
                        assert!(c.k1 - c.k0 <= c.k0.max(1), "chunk {c:?} too eager");
                    }
                }
            }
        }
    }

    #[test]
    fn worker_clock_unions_overlapping_spans() {
        let clock = WorkerClock::new();
        let wall = Instant::now();
        let a = clock.enter();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let b = clock.enter();
        std::thread::sleep(std::time::Duration::from_millis(10));
        drop(a);
        std::thread::sleep(std::time::Duration::from_millis(10));
        drop(b);
        let busy = clock.take_ns();
        let wall = wall.elapsed().as_nanos() as u64;
        // union spans all three sleeps once; the naive per-span sum
        // (10+10 + 10+10 = 40ms of sleeps) would exceed wall time on a
        // hypothetical 30ms wall — the union never can
        assert!(busy >= 30_000_000, "busy {busy}ns < 30ms");
        assert!(busy <= wall, "busy {busy}ns exceeds wall {wall}ns");
        assert_eq!(clock.take_ns(), 0, "drained");
    }

    #[test]
    fn worker_clock_folds_open_spans_into_take() {
        let clock = WorkerClock::new();
        let g = clock.enter();
        std::thread::sleep(std::time::Duration::from_millis(5));
        let first = clock.take_ns();
        assert!(first >= 5_000_000, "open span folded in: {first}");
        std::thread::sleep(std::time::Duration::from_millis(5));
        drop(g);
        let second = clock.take_ns();
        assert!(second >= 5_000_000, "span restarted at take: {second}");
    }

    // AsyncTau end-to-end behaviour (bit-identical unsplit output at
    // mixer_workers ∈ {1, 2, 4}, tolerance-bounded split output, fence
    // ordering under churn, drop-mid-flight drain) is covered against
    // real artifacts in tests/integration_async.rs.
}
