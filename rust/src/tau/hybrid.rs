//! Hybrid τ (§5.3): dynamically choose the best implementation for each
//! tile size U from a calibration table (the "isolated empirically-measured
//! efficiency of each implementation"). This is the paper's best method —
//! it traces the per-U Pareto frontier of Fig 3a.

use anyhow::Result;

use super::{
    CalibrationTable, PjrtDirect, PjrtFft, RhoCache, RustDirect, RustFft, TauImpl, TauKind,
};
use crate::tiling::Tile;
use crate::util::tensor::CellTensor;

pub struct Hybrid<'c, 'rt> {
    table: CalibrationTable,
    rust_direct: RustDirect<'c, 'rt>,
    rust_fft: RustFft<'c, 'rt>,
    pjrt_direct: PjrtDirect<'c, 'rt>,
    pjrt_fft: PjrtFft<'c, 'rt>,
}

impl<'c, 'rt> Hybrid<'c, 'rt> {
    pub fn new(cache: &'c RhoCache<'rt>, table: CalibrationTable, threads: usize) -> Self {
        Hybrid {
            table,
            rust_direct: RustDirect::new(cache, threads),
            rust_fft: RustFft::new(cache, threads),
            pjrt_direct: PjrtDirect::new(cache),
            pjrt_fft: PjrtFft::new(cache),
        }
    }

    /// Load `hybrid.json` from the artifact dir if present (written by
    /// `flashinfer calibrate`), else use the built-in heuristic.
    pub fn from_default(cache: &'c RhoCache<'rt>, threads: usize) -> Result<Hybrid<'c, 'rt>> {
        let path = cache.runtime().dir.join("hybrid.json");
        let table = if path.exists() {
            CalibrationTable::load(&path)?
        } else {
            CalibrationTable::heuristic(cache.runtime().dims.l)
        };
        Ok(Hybrid::new(cache, table, threads))
    }

    pub fn choice(&self, u: usize) -> TauKind {
        self.table.choice(u)
    }

    pub fn table(&self) -> &CalibrationTable {
        &self.table
    }
}

impl TauImpl for Hybrid<'_, '_> {
    fn kind(&self) -> TauKind {
        TauKind::Hybrid
    }

    fn apply(&mut self, streams: &CellTensor, pending: &CellTensor, tile: Tile) -> Result<()> {
        match self.table.choice(tile.u) {
            TauKind::RustDirect => self.rust_direct.apply(streams, pending, tile),
            TauKind::RustFft => self.rust_fft.apply(streams, pending, tile),
            TauKind::PjrtDirect => self.pjrt_direct.apply(streams, pending, tile),
            TauKind::PjrtFft => self.pjrt_fft.apply(streams, pending, tile),
            TauKind::Hybrid => unreachable!("calibration tables hold fixed kinds"),
        }
    }

    fn tile_flops(&self, u: usize, g: usize, d: usize) -> u64 {
        self.table.choice(u).tile_flops(u, g, d)
    }
}
