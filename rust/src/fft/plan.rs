//! FFT plans: precomputed twiddle factors + bit-reversal permutation,
//! shared by the scalar and vectorized kernels and cached per size.
//!
//! This is the paper's §5.4(4) "pre-initialized configurations": plans (and
//! filter spectra, see `tau::rho_cache`) are built once per tile size at
//! engine init, never on the token loop.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::complex::Cpx;

/// Plan for a radix-2 FFT of (power-of-two) size `n`.
#[derive(Debug)]
pub struct Plan {
    pub n: usize,
    pub log2n: u32,
    /// Row permutation: bitrev[i] = bit-reversed i (applied pre-butterfly).
    pub bitrev: Vec<u32>,
    /// Forward twiddles w^k = e^{-2*pi*i*k/n}, k in [0, n/2).
    pub tw_re: Vec<f32>,
    pub tw_im: Vec<f32>,
}

impl Plan {
    pub fn new(n: usize) -> Plan {
        assert!(n.is_power_of_two() && n >= 1, "fft size must be a power of two, got {n}");
        let log2n = n.trailing_zeros();
        let mut bitrev = vec![0u32; n];
        for i in 0..n {
            bitrev[i] = (i as u32).reverse_bits() >> (32 - log2n.max(1)) as u32;
        }
        if n == 1 {
            bitrev[0] = 0;
        }
        let half = (n / 2).max(1);
        let mut tw_re = Vec::with_capacity(half);
        let mut tw_im = Vec::with_capacity(half);
        for k in 0..half {
            let w = Cpx::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64);
            tw_re.push(w.re);
            tw_im.push(w.im);
        }
        Plan { n, log2n, bitrev, tw_re, tw_im }
    }

    /// Apply the bit-reversal permutation to `n` rows of width `d`
    /// (in-place swap of whole rows; `data.len() == n * d`).
    pub fn permute_rows(&self, data: &mut [f32], d: usize) {
        debug_assert_eq!(data.len(), self.n * d);
        for i in 0..self.n {
            let j = self.bitrev[i] as usize;
            if i < j {
                let (lo, hi) = data.split_at_mut(j * d);
                lo[i * d..i * d + d].swap_with_slice(&mut hi[..d]);
            }
        }
    }
}

/// Process-wide plan cache. Plans are immutable once built.
pub struct PlanCache {
    plans: Mutex<HashMap<usize, Arc<Plan>>>,
}

impl PlanCache {
    pub fn new() -> PlanCache {
        PlanCache { plans: Mutex::new(HashMap::new()) }
    }

    pub fn get(&self, n: usize) -> Arc<Plan> {
        let mut m = self.plans.lock().unwrap();
        m.entry(n).or_insert_with(|| Arc::new(Plan::new(n))).clone()
    }

    /// Shared global cache (plans are pure functions of n).
    pub fn global() -> &'static PlanCache {
        static CACHE: OnceLock<PlanCache> = OnceLock::new();
        CACHE.get_or_init(PlanCache::new)
    }
}

impl Default for PlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitrev_is_an_involution() {
        for n in [1usize, 2, 4, 8, 64, 256] {
            let p = Plan::new(n);
            for i in 0..n {
                let j = p.bitrev[i] as usize;
                assert_eq!(p.bitrev[j] as usize, i, "n={n} i={i}");
                assert!(j < n);
            }
        }
    }

    #[test]
    fn twiddles_lie_on_unit_circle() {
        let p = Plan::new(16);
        for k in 0..8 {
            let mag = (p.tw_re[k] * p.tw_re[k] + p.tw_im[k] * p.tw_im[k]).sqrt();
            assert!((mag - 1.0).abs() < 1e-6);
        }
        assert_eq!(p.tw_re[0], 1.0);
        assert_eq!(p.tw_im[0], 0.0);
        // w^{n/4} = -i for n=16 -> k=4
        assert!((p.tw_re[4]).abs() < 1e-6);
        assert!((p.tw_im[4] + 1.0).abs() < 1e-6);
    }

    #[test]
    fn permute_rows_known_order() {
        let p = Plan::new(4); // bitrev of [0,1,2,3] = [0,2,1,3]
        let mut data = vec![0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 3.0, 3.0];
        p.permute_rows(&mut data, 2);
        assert_eq!(data, vec![0.0, 0.0, 2.0, 2.0, 1.0, 1.0, 3.0, 3.0]);
    }

    #[test]
    #[should_panic(expected = "power of two")]
    fn non_pow2_rejected() {
        Plan::new(12);
    }

    #[test]
    fn cache_returns_same_plan() {
        let c = PlanCache::new();
        let a = c.get(64);
        let b = c.get(64);
        assert!(Arc::ptr_eq(&a, &b));
    }
}
