//! Real-input FFT (rfft / irfft) over SoA `[n][d]` planes — the
//! half-spectrum pipeline for the native τ hot path.
//!
//! Tile inputs and the filter prefix are purely real, so their order-n DFTs
//! are conjugate-symmetric: bins `[0, n/2]` determine the rest. We exploit
//! this with the standard pack-two-halves trick: fold the n real samples
//! into an order-n/2 *complex* sequence `z[k] = x[2k] + i·x[2k+1]`, run one
//! complex transform of half the order, and recover the `n/2 + 1` retained
//! bins with an O(n) twiddle pass. Relative to the full complex path this
//! halves transform FLOPs, scratch traffic, and cached-spectrum memory —
//! the same engineering FlashFFTConv applies to its real convolutions.
//!
//! Conventions match `vecfft`: `d` is the contiguous lane axis, the inverse
//! is unscaled (the 1/n folds into the consumer's accumulation), and all
//! kernels are allocation-free given caller scratch.

use std::collections::HashMap;
use std::sync::{Arc, Mutex, OnceLock};

use super::complex::Cpx;
use super::plan::{Plan, PlanCache};
use super::simd;
use super::vecfft;

/// Plan for a real FFT of (even, power-of-two) order `n`: the order-n/2
/// complex plan for the packed transform plus the split twiddles
/// `e^{-2πik/n}`, k ∈ [0, n/2], for the pack/unpack passes.
#[derive(Debug)]
pub struct RfftPlan {
    /// Real transform order (the tile's 2U).
    pub n: usize,
    /// Packed complex transform order n/2.
    pub m: usize,
    /// Complex plan of order `m` shared with any other user of that size.
    pub half: Arc<Plan>,
    pub(crate) tw_re: Vec<f32>,
    pub(crate) tw_im: Vec<f32>,
}

impl RfftPlan {
    pub fn new(n: usize) -> RfftPlan {
        assert!(
            n >= 2 && n.is_power_of_two(),
            "rfft order must be an even power of two, got {n}"
        );
        RfftPlan::with_half(n, Arc::new(Plan::new(n / 2)))
    }

    fn with_half(n: usize, half: Arc<Plan>) -> RfftPlan {
        let m = n / 2;
        debug_assert_eq!(half.n, m);
        let mut tw_re = Vec::with_capacity(m + 1);
        let mut tw_im = Vec::with_capacity(m + 1);
        for k in 0..=m {
            let w = Cpx::cis(-2.0 * std::f64::consts::PI * k as f64 / n as f64);
            tw_re.push(w.re);
            tw_im.push(w.im);
        }
        RfftPlan { n, m, half, tw_re, tw_im }
    }

    /// Number of retained half-spectrum bins, n/2 + 1.
    pub fn bins(&self) -> usize {
        self.m + 1
    }
}

/// Forward rfft of real rows `x` (`[rows][d]`, rows ≤ n; logically
/// zero-padded to n rows) into half-spectrum planes `out_re`/`out_im`
/// (`[(n/2+1)][d]`). `zre`/`zim` are `[n/2][d]` scratch for the packed
/// transform; every output and scratch cell is overwritten.
pub fn rfft_into(
    plan: &RfftPlan,
    x: &[f32],
    out_re: &mut [f32],
    out_im: &mut [f32],
    zre: &mut [f32],
    zim: &mut [f32],
    d: usize,
) {
    let m = plan.m;
    debug_assert!(x.len() <= plan.n * d && x.len() % d == 0);
    debug_assert_eq!(out_re.len(), (m + 1) * d);
    debug_assert_eq!(out_im.len(), (m + 1) * d);
    debug_assert_eq!(zre.len(), m * d);
    debug_assert_eq!(zim.len(), m * d);

    // pack: z[k] = x[2k] + i·x[2k+1], zero rows past the provided input
    let rows = x.len() / d;
    for k in 0..m {
        let (even, odd) = (2 * k, 2 * k + 1);
        let zr = &mut zre[k * d..(k + 1) * d];
        if even < rows {
            zr.copy_from_slice(&x[even * d..(even + 1) * d]);
        } else {
            zr.fill(0.0);
        }
        let zi = &mut zim[k * d..(k + 1) * d];
        if odd < rows {
            zi.copy_from_slice(&x[odd * d..(odd + 1) * d]);
        } else {
            zi.fill(0.0);
        }
    }

    vecfft::forward(&plan.half, zre, zim, d);

    // unpack: split Z into the even/odd-sample spectra and recombine.
    // X[k] = E[k] + w^k·O[k] with E[k] = (Z[k] + conj(Z[m-k]))/2,
    // O[k] = -i·(Z[k] - conj(Z[m-k]))/2, Z[m] ≡ Z[0].
    // Endpoints are real: X[0] = Re Z₀ + Im Z₀, X[m] = Re Z₀ - Im Z₀.
    {
        let (x0_re, xm_re) = out_re.split_at_mut(m * d);
        let (x0_im, xm_im) = out_im.split_at_mut(m * d);
        simd::rfft_endpoints_row(
            &mut x0_re[..d],
            &mut x0_im[..d],
            &mut xm_re[..d],
            &mut xm_im[..d],
            &zre[..d],
            &zim[..d],
        );
    }
    for k in 1..m {
        let j = m - k;
        let (wr, wi) = (plan.tw_re[k], plan.tw_im[k]);
        simd::rfft_unpack_row(
            &mut out_re[k * d..(k + 1) * d],
            &mut out_im[k * d..(k + 1) * d],
            &zre[k * d..(k + 1) * d],
            &zim[k * d..(k + 1) * d],
            &zre[j * d..(j + 1) * d],
            &zim[j * d..(j + 1) * d],
            wr,
            wi,
        );
    }
}

/// Inverse rfft of half-spectrum planes (`[(n/2+1)][d]`) to the *packed*
/// time domain, unscaled: on return `zre[k] = n·x[2k]`, `zim[k] =
/// n·x[2k+1]`. Consumers that only need a row range (the tile kernel keeps
/// rows [U, 2U)) read the packed planes directly and skip a deinterleave
/// pass; fold the 1/n into the read.
pub fn irfft_packed_unscaled(
    plan: &RfftPlan,
    spec_re: &[f32],
    spec_im: &[f32],
    zre: &mut [f32],
    zim: &mut [f32],
    d: usize,
) {
    let m = plan.m;
    debug_assert_eq!(spec_re.len(), (m + 1) * d);
    debug_assert_eq!(spec_im.len(), (m + 1) * d);
    debug_assert_eq!(zre.len(), m * d);
    debug_assert_eq!(zim.len(), m * d);

    // repack: 2·Z[k] = (X[k] + conj(X[m-k])) + i·conj(w^k)·(X[k] - conj(X[m-k]));
    // the factor 2 delivers n·x from the order-m unscaled inverse (m = n/2).
    for k in 0..m {
        let j = m - k; // X has m+1 bins, so no wrap-around
        let (wr, wi) = (plan.tw_re[k], plan.tw_im[k]);
        simd::irfft_repack_row(
            &mut zre[k * d..(k + 1) * d],
            &mut zim[k * d..(k + 1) * d],
            &spec_re[k * d..(k + 1) * d],
            &spec_im[k * d..(k + 1) * d],
            &spec_re[j * d..(j + 1) * d],
            &spec_im[j * d..(j + 1) * d],
            wr,
            wi,
        );
    }

    vecfft::inverse_unscaled(&plan.half, zre, zim, d);
}

/// Full inverse rfft: deinterleave the packed result into `out` (`[n][d]`,
/// unscaled by n — fold 1/n into the consumer, as `vecfft`).
pub fn irfft_unscaled_into(
    plan: &RfftPlan,
    spec_re: &[f32],
    spec_im: &[f32],
    out: &mut [f32],
    zre: &mut [f32],
    zim: &mut [f32],
    d: usize,
) {
    debug_assert_eq!(out.len(), plan.n * d);
    irfft_packed_unscaled(plan, spec_re, spec_im, zre, zim, d);
    for k in 0..plan.m {
        out[2 * k * d..(2 * k + 1) * d].copy_from_slice(&zre[k * d..(k + 1) * d]);
        out[(2 * k + 1) * d..(2 * k + 2) * d].copy_from_slice(&zim[k * d..(k + 1) * d]);
    }
}

/// Pointwise half-spectrum product. Both operands are spectra of real
/// signals, hence conjugate-symmetric: multiplying bins [0, n/2] *is* the
/// full order-n pointwise product (the mirrored half is the conjugate of
/// this one by construction).
pub fn cmul_halfspec_inplace(re: &mut [f32], im: &mut [f32], bre: &[f32], bim: &[f32]) {
    vecfft::cmul_inplace(re, im, bre, bim);
}

/// Half-spectrum planes of a real filter segment — the rfft analogue of
/// [`super::conv::spectrum_planes`]: `seg` is `[rows][d]` (rows ≤ n,
/// zero-padded), the result is `([(n/2+1)][d], [(n/2+1)][d])` re/im — the
/// exact `[0, n/2]`-bin layout the PJRT `@rho_re/@rho_im` buffers consume.
pub fn spectrum_halfplanes(plan: &RfftPlan, seg: &[f32], d: usize) -> (Vec<f32>, Vec<f32>) {
    let bins = plan.bins();
    let mut re = vec![0.0f32; bins * d];
    let mut im = vec![0.0f32; bins * d];
    let mut zre = vec![0.0f32; plan.m * d];
    let mut zim = vec![0.0f32; plan.m * d];
    rfft_into(plan, seg, &mut re, &mut im, &mut zre, &mut zim, d);
    (re, im)
}

/// Process-wide rfft plan cache; the packed complex plans are shared
/// through an inner [`PlanCache`].
pub struct RfftPlanCache {
    plans: Mutex<HashMap<usize, Arc<RfftPlan>>>,
    half: PlanCache,
}

impl RfftPlanCache {
    pub fn new() -> RfftPlanCache {
        RfftPlanCache { plans: Mutex::new(HashMap::new()), half: PlanCache::new() }
    }

    pub fn get(&self, n: usize) -> Arc<RfftPlan> {
        if let Some(p) = self.plans.lock().unwrap().get(&n) {
            return p.clone();
        }
        // build outside the map lock: Plan::new(n/2) is the expensive part
        assert!(n >= 2 && n.is_power_of_two(), "rfft order must be an even power of two, got {n}");
        let plan = Arc::new(RfftPlan::with_half(n, self.half.get(n / 2)));
        self.plans.lock().unwrap().entry(n).or_insert(plan).clone()
    }

    /// Shared global cache (plans are pure functions of n).
    pub fn global() -> &'static RfftPlanCache {
        static CACHE: OnceLock<RfftPlanCache> = OnceLock::new();
        CACHE.get_or_init(RfftPlanCache::new)
    }
}

impl Default for RfftPlanCache {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::conv::spectrum_planes;
    use crate::util::prng::Prng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn rfft_of(x: &[f32], n: usize, d: usize) -> (Vec<f32>, Vec<f32>) {
        let plan = RfftPlan::new(n);
        let mut re = vec![0.0f32; plan.bins() * d];
        let mut im = vec![0.0f32; plan.bins() * d];
        let mut zre = vec![0.0f32; plan.m * d];
        let mut zim = vec![0.0f32; plan.m * d];
        rfft_into(&plan, x, &mut re, &mut im, &mut zre, &mut zim, d);
        (re, im)
    }

    #[test]
    fn forward_matches_full_complex_fft_half() {
        for (n, d) in [(2usize, 1usize), (4, 3), (8, 2), (64, 5), (512, 8)] {
            let x = rand_vec(n * d, (n + d) as u64);
            let (re, im) = rfft_of(&x, n, d);
            // reference: full complex DFT of the same real input
            let full = Plan::new(n);
            let (fre, fim) = spectrum_planes(&full, &x, d);
            for k in 0..=n / 2 {
                for t in 0..d {
                    let tol = 1e-3 * (n as f32).sqrt();
                    assert!(
                        (re[k * d + t] - fre[k * d + t]).abs() < tol,
                        "n={n} d={d} bin={k}: {} vs {}",
                        re[k * d + t],
                        fre[k * d + t]
                    );
                    assert!((im[k * d + t] - fim[k * d + t]).abs() < tol);
                }
            }
        }
    }

    #[test]
    fn forward_zero_pads_short_input() {
        let (n, d) = (16usize, 3usize);
        let rows = 5;
        let x = rand_vec(rows * d, 11);
        let mut padded = x.clone();
        padded.resize(n * d, 0.0);
        let (re_a, im_a) = rfft_of(&x, n, d);
        let (re_b, im_b) = rfft_of(&padded, n, d);
        assert_eq!(re_a, re_b);
        assert_eq!(im_a, im_b);
    }

    #[test]
    fn dc_and_nyquist_bins_are_real() {
        let (n, d) = (32usize, 4usize);
        let x = rand_vec(n * d, 21);
        let (_re, im) = rfft_of(&x, n, d);
        for t in 0..d {
            assert_eq!(im[t], 0.0);
            assert_eq!(im[(n / 2) * d + t], 0.0);
        }
    }

    #[test]
    fn roundtrip_recovers_input() {
        for (n, d) in [(2usize, 2usize), (8, 1), (64, 16), (512, 8)] {
            let plan = RfftPlan::new(n);
            let x = rand_vec(n * d, 99 + n as u64);
            let mut re = vec![0.0f32; plan.bins() * d];
            let mut im = vec![0.0f32; plan.bins() * d];
            let mut zre = vec![0.0f32; plan.m * d];
            let mut zim = vec![0.0f32; plan.m * d];
            rfft_into(&plan, &x, &mut re, &mut im, &mut zre, &mut zim, d);
            let mut out = vec![0.0f32; n * d];
            irfft_unscaled_into(&plan, &re, &im, &mut out, &mut zre, &mut zim, d);
            let s = 1.0 / n as f32;
            for k in 0..n * d {
                assert!((out[k] * s - x[k]).abs() < 1e-4, "n={n} d={d} k={k}");
            }
        }
    }

    #[test]
    fn halfplanes_match_full_spectrum_prefix() {
        let (n, d) = (64usize, 6usize);
        let seg = rand_vec(40 * d, 7); // shorter than n: zero-padded
        let rplan = RfftPlan::new(n);
        let (hre, him) = spectrum_halfplanes(&rplan, &seg, d);
        let full = Plan::new(n);
        let (fre, fim) = spectrum_planes(&full, &seg, d);
        assert_eq!(hre.len(), (n / 2 + 1) * d);
        for k in 0..(n / 2 + 1) * d {
            assert!((hre[k] - fre[k]).abs() < 1e-3);
            assert!((him[k] - fim[k]).abs() < 1e-3);
        }
    }

    #[test]
    fn order_two_closed_form() {
        // n = 2: X = [x0 + x1, x0 - x1]
        let x = vec![3.0f32, -1.5];
        let (re, im) = rfft_of(&x, 2, 1);
        assert!((re[0] - 1.5).abs() < 1e-6);
        assert!((re[1] - 4.5).abs() < 1e-6);
        assert_eq!(im, vec![0.0, 0.0]);
    }

    #[test]
    fn cache_returns_same_plan_and_shares_half() {
        let c = RfftPlanCache::new();
        let a = c.get(64);
        let b = c.get(64);
        assert!(Arc::ptr_eq(&a, &b));
        let other = c.get(128);
        assert_eq!(other.m, 64);
        assert_eq!(a.m, 32);
    }
}
