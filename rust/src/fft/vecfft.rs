//! Vectorized (structure-of-arrays) FFT: one radix-2 transform over the
//! time axis applied to D channel lanes at once.
//!
//! This is the native hot path for the FFT tau implementation. Data layout
//! is two planes `re`, `im`, each `[n][d]` row-major — every butterfly
//! touches whole contiguous D-rows. The row loops dispatch through
//! `fft::simd` (runtime AVX2/NEON under `--features simd`, scalar
//! reference otherwise — bit-identical either way; see DESIGN.md §9),
//! which mirrors exactly how the Pallas kernel lays the tile out in VMEM
//! (DESIGN.md §Hardware-Adaptation): `d` is the lane axis on both targets.

use super::plan::Plan;
use super::simd;

/// Forward transform over the first axis of `[n][d]` planes.
pub fn forward(plan: &Plan, re: &mut [f32], im: &mut [f32], d: usize) {
    transform::<false>(plan, re, im, d);
}

/// Inverse transform *without* 1/n scaling (fold it into the consumer).
pub fn inverse_unscaled(plan: &Plan, re: &mut [f32], im: &mut [f32], d: usize) {
    transform::<true>(plan, re, im, d);
}

fn transform<const INV: bool>(plan: &Plan, re: &mut [f32], im: &mut [f32], d: usize) {
    let n = plan.n;
    debug_assert_eq!(re.len(), n * d);
    debug_assert_eq!(im.len(), n * d);
    if n == 1 {
        return;
    }
    plan.permute_rows(re, d);
    plan.permute_rows(im, d);

    let mut len = 1;
    while len < n {
        let step = n / (2 * len);
        for base in (0..n).step_by(2 * len) {
            for j in 0..len {
                let wre = plan.tw_re[j * step];
                let wim = if INV { -plan.tw_im[j * step] } else { plan.tw_im[j * step] };
                let (ai, bi) = (base + j, base + j + len);
                // butterfly over the D lanes of rows ai and bi
                let (re_a, re_b) = split_rows(re, ai, bi, d);
                let (im_a, im_b) = split_rows(im, ai, bi, d);
                if wim == 0.0 && wre == 1.0 {
                    // twiddle-free butterfly (j == 0): saves 4 mults/lane
                    simd::butterfly_rows_w1(re_a, im_a, re_b, im_b);
                } else {
                    simd::butterfly_rows(re_a, im_a, re_b, im_b, wre, wim);
                }
            }
        }
        len *= 2;
    }
}

/// Disjoint mutable views of rows `a < b`, each `d` long.
#[inline]
fn split_rows(data: &mut [f32], a: usize, b: usize, d: usize) -> (&mut [f32], &mut [f32]) {
    debug_assert!(a < b);
    let (lo, hi) = data.split_at_mut(b * d);
    (&mut lo[a * d..a * d + d], &mut hi[..d])
}

/// Pointwise complex multiply-accumulate free product:
/// (re, im) *= (bre, bim), all planes `[n][d]`.
pub fn cmul_inplace(re: &mut [f32], im: &mut [f32], bre: &[f32], bim: &[f32]) {
    debug_assert_eq!(re.len(), bre.len());
    simd::cmul_rows(re, im, bre, bim);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fft::complex::Cpx;
    use crate::fft::radix2;
    use crate::util::prng::Prng;

    fn rand_planes(n: usize, d: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let mut rng = Prng::new(seed);
        let re = (0..n * d).map(|_| rng.normal_f32()).collect();
        let im = (0..n * d).map(|_| rng.normal_f32()).collect();
        (re, im)
    }

    #[test]
    fn matches_scalar_fft_per_lane() {
        for (n, d) in [(2usize, 1usize), (8, 3), (32, 5), (128, 64)] {
            let plan = Plan::new(n);
            let (mut re, mut im) = rand_planes(n, d, (n + d) as u64);
            let orig_re = re.clone();
            let orig_im = im.clone();
            forward(&plan, &mut re, &mut im, d);
            for lane in 0..d {
                let mut scalar: Vec<Cpx> = (0..n)
                    .map(|t| Cpx::new(orig_re[t * d + lane], orig_im[t * d + lane]))
                    .collect();
                radix2::forward(&plan, &mut scalar);
                for t in 0..n {
                    assert!(
                        (re[t * d + lane] - scalar[t].re).abs() < 2e-3,
                        "n={n} d={d} lane={lane} t={t}"
                    );
                    assert!((im[t * d + lane] - scalar[t].im).abs() < 2e-3);
                }
            }
        }
    }

    #[test]
    fn forward_inverse_roundtrip() {
        for (n, d) in [(4usize, 2usize), (64, 16), (512, 8)] {
            let plan = Plan::new(n);
            let (mut re, mut im) = rand_planes(n, d, 99);
            let orig_re = re.clone();
            let orig_im = im.clone();
            forward(&plan, &mut re, &mut im, d);
            inverse_unscaled(&plan, &mut re, &mut im, d);
            let s = 1.0 / n as f32;
            for k in 0..n * d {
                assert!((re[k] * s - orig_re[k]).abs() < 1e-4, "n={n}");
                assert!((im[k] * s - orig_im[k]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn cmul_matches_complex_mul() {
        let a = Cpx::new(1.5, -2.0);
        let b = Cpx::new(0.5, 3.0);
        let mut re = vec![a.re];
        let mut im = vec![a.im];
        cmul_inplace(&mut re, &mut im, &[b.re], &[b.im]);
        let want = a * b;
        assert!((re[0] - want.re).abs() < 1e-6);
        assert!((im[0] - want.im).abs() < 1e-6);
    }

    #[test]
    fn n_equals_one_is_identity() {
        let plan = Plan::new(1);
        let mut re = vec![3.0, 4.0];
        let mut im = vec![-1.0, 2.0];
        forward(&plan, &mut re, &mut im, 2);
        assert_eq!(re, vec![3.0, 4.0]);
        assert_eq!(im, vec![-1.0, 2.0]);
    }
}
