//! FFT tile convolution (Lemma 1 + Appendix C) on the vectorized FFT.
//!
//! The tile at iteration i contributes streams[i-U+1..i] to pending
//! [i+1..i+U]. Appendix C shows one *cyclic* convolution of order 2U
//! suffices (the wrap-around lands outside the kept slice), and that the
//! filter-prefix spectrum can be precomputed per (layer, U) — dropping the
//! per-tile cost from 3 DFTs to 2.
//!
//! Two pipelines implement the same tile: [`tile_conv_fft_into`] on full
//! complex spectra (the original kernel, kept as the comparison baseline)
//! and [`tile_conv_rfft_into`] on real-input half-spectra (the hot path:
//! packed transforms of order U, U+1 cached filter bins — see `fft::rfft`).

use super::plan::Plan;
use super::rfft::{self, RfftPlan};
use super::vecfft;

/// Reusable scratch planes for tile convolutions (sized to the largest
/// tile at engine init; no allocation on the token loop).
///
/// The complex path uses the `re`/`im` pair at the full transform order n;
/// the rfft path reuses the same pair at order n/2 for the packed
/// transform and adds a half-spectrum pair of n/2 + 1 bins.
#[derive(Debug, Default)]
pub struct TileScratch {
    re: Vec<f32>,
    im: Vec<f32>,
    half_re: Vec<f32>,
    half_im: Vec<f32>,
}

impl TileScratch {
    pub fn with_capacity(max_n: usize, d: usize) -> TileScratch {
        TileScratch {
            re: vec![0.0; max_n * d],
            im: vec![0.0; max_n * d],
            half_re: vec![0.0; (max_n / 2 + 1) * d],
            half_im: vec![0.0; (max_n / 2 + 1) * d],
        }
    }

    fn planes(&mut self, n: usize, d: usize) -> (&mut [f32], &mut [f32]) {
        let len = n * d;
        if self.re.len() < len {
            self.re.resize(len, 0.0);
            self.im.resize(len, 0.0);
        }
        (&mut self.re[..len], &mut self.im[..len])
    }

    /// Packed (`[n/2][d]`) + half-spectrum (`[n/2+1][d]`) planes for the
    /// rfft pipeline at transform order `n`.
    #[allow(clippy::type_complexity)]
    fn rfft_planes(
        &mut self,
        n: usize,
        d: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32], &mut [f32]) {
        let zlen = (n / 2) * d;
        let xlen = (n / 2 + 1) * d;
        if self.re.len() < zlen {
            self.re.resize(zlen, 0.0);
            self.im.resize(zlen, 0.0);
        }
        if self.half_re.len() < xlen {
            self.half_re.resize(xlen, 0.0);
            self.half_im.resize(xlen, 0.0);
        }
        (
            &mut self.re[..zlen],
            &mut self.im[..zlen],
            &mut self.half_re[..xlen],
            &mut self.half_im[..xlen],
        )
    }
}

/// Precompute the spectrum planes of a real filter segment.
///
/// `seg` is `[m][d]` (m <= plan.n; zero-padded). Returns `([n][d], [n][d])`
/// re/im planes of its order-n DFT — the layout both the native path and
/// the `tau_fft` PJRT artifacts consume (artifacts take bins `[0, n/2]`).
pub fn spectrum_planes(plan: &Plan, seg: &[f32], d: usize) -> (Vec<f32>, Vec<f32>) {
    let n = plan.n;
    assert!(seg.len() <= n * d && seg.len() % d == 0);
    let mut re = vec![0.0f32; n * d];
    let mut im = vec![0.0f32; n * d];
    re[..seg.len()].copy_from_slice(seg);
    vecfft::forward(plan, &mut re, &mut im, d);
    (re, im)
}

/// FFT tile: `out_add[k][:] += sum_j y[j][:] * rho[U+k-j][:]` using the
/// precomputed filter spectrum.
///
/// * `plan`    — order-2U plan.
/// * `y`       — `[U][d]` contiguous tile input.
/// * `spec_*`  — `[2U][d]` filter-prefix spectrum planes.
/// * `out_add` — `[U][d]`; the middle-U slice of the cyclic convolution is
///   accumulated into it (the paper aggregates tiles in place, §3.3).
///
/// PERF NOTE: a D-blocked (cache-tiled) variant was measured at
/// BLOCK_D ∈ {8, 16, 32} and was neutral-to-worse on this machine (the
/// [2U][D] working set already streams well at D = 64; see EXPERIMENTS.md
/// §Perf iteration log), so the simple whole-width path is kept.
pub fn tile_conv_fft_into(
    plan: &Plan,
    y: &[f32],
    spec_re: &[f32],
    spec_im: &[f32],
    out_add: &mut [f32],
    scratch: &mut TileScratch,
    d: usize,
) {
    let n = plan.n;
    let u = n / 2;
    debug_assert_eq!(y.len(), u * d);
    debug_assert_eq!(spec_re.len(), n * d);
    debug_assert_eq!(out_add.len(), u * d);

    let (re, im) = scratch.planes(n, d);
    re[..u * d].copy_from_slice(y);
    re[u * d..].fill(0.0);
    im.fill(0.0);

    vecfft::forward(plan, re, im, d);
    vecfft::cmul_inplace(re, im, spec_re, spec_im);
    vecfft::inverse_unscaled(plan, re, im, d);

    // keep rows [U, 2U), fold in the 1/n inverse scale during accumulation
    let s = 1.0 / n as f32;
    let tail = &re[u * d..n * d];
    for (o, v) in out_add.iter_mut().zip(tail) {
        *o += v * s;
    }
}

/// Rfft tile: same contract as [`tile_conv_fft_into`] but on the real-input
/// half-spectrum pipeline — the native τ hot path.
///
/// * `plan`    — rfft plan of real order 2U.
/// * `y`       — `[U][d]` contiguous tile input (real; zero-padded to 2U).
/// * `spec_*`  — `[(U+1)][d]` filter-prefix *half*-spectrum planes
///   (bins [0, U] of the order-2U DFT; see [`rfft::spectrum_halfplanes`]).
/// * `out_add` — `[U][d]`; the middle-U slice of the order-2U cyclic
///   convolution is accumulated into it, 1/n folded into the accumulation.
///
/// Both packed transforms run at order U instead of 2U and the pointwise
/// product touches U+1 bins instead of 2U — roughly half the FLOPs and
/// scratch traffic of the complex path, with identical results up to
/// rounding (proven against `tile_conv_direct_into` in the tests below).
pub fn tile_conv_rfft_into(
    plan: &RfftPlan,
    y: &[f32],
    spec_re: &[f32],
    spec_im: &[f32],
    out_add: &mut [f32],
    scratch: &mut TileScratch,
    d: usize,
) {
    let n = plan.n;
    let u = n / 2;
    debug_assert_eq!(y.len(), u * d);
    debug_assert_eq!(spec_re.len(), (u + 1) * d);
    debug_assert_eq!(spec_im.len(), (u + 1) * d);
    debug_assert_eq!(out_add.len(), u * d);

    let (zre, zim, xre, xim) = scratch.rfft_planes(n, d);
    rfft::rfft_into(plan, y, xre, xim, zre, zim, d);
    rfft::cmul_halfspec_inplace(xre, xim, spec_re, spec_im);
    rfft::irfft_packed_unscaled(plan, xre, xim, zre, zim, d);

    // keep rows [U, 2U) of the (n-scaled) cyclic convolution; the packed
    // layout interleaves them as zre[k] = n·x[2k], zim[k] = n·x[2k+1].
    let s = 1.0 / n as f32;
    if u == 1 {
        // the single kept row (t = 1) is odd: it lives in the im plane
        for t in 0..d {
            out_add[t] += zim[t] * s;
        }
    } else {
        for k in u / 2..u {
            let r0 = (2 * k - u) * d; // even kept row ← re plane
            for t in 0..d {
                out_add[r0 + t] += zre[k * d + t] * s;
                out_add[r0 + d + t] += zim[k * d + t] * s;
            }
        }
    }
}

/// O(U^2 d) reference tile (also the core of the `rust_direct` tau impl):
/// `out_add[k][:] += sum_j y[j][:] * rho_seg[U+k-j][:]`.
pub fn tile_conv_direct_into(y: &[f32], rho_seg: &[f32], out_add: &mut [f32], d: usize) {
    let u = y.len() / d;
    debug_assert_eq!(y.len(), u * d);
    debug_assert_eq!(rho_seg.len(), 2 * u * d);
    debug_assert_eq!(out_add.len(), u * d);
    // loop order: j outer so both rho rows and out rows stream contiguously
    for j in 0..u {
        let yj = &y[j * d..(j + 1) * d];
        // out[k] += yj * rho[U + k - j], k = 0..U  => rho rows U-j .. 2U-j
        let rho_base = (u - j) * d;
        for k in 0..u {
            let r = &rho_seg[rho_base + k * d..rho_base + (k + 1) * d];
            let o = &mut out_add[k * d..(k + 1) * d];
            for t in 0..d {
                o[t] += yj[t] * r[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn naive_tile(y: &[f32], rho: &[f32], u: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; u * d];
        for k in 0..u {
            for j in 0..u {
                let lag = u + k - j;
                for t in 0..d {
                    out[k * d + t] += y[j * d + t] * rho[lag * d + t];
                }
            }
        }
        out
    }

    #[test]
    fn direct_matches_naive() {
        for (u, d) in [(1usize, 1usize), (2, 3), (8, 4), (16, 64)] {
            let y = rand_vec(u * d, 1);
            let rho = rand_vec(2 * u * d, 2);
            let mut out = vec![0.0f32; u * d];
            tile_conv_direct_into(&y, &rho, &mut out, d);
            let want = naive_tile(&y, &rho, u, d);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "u={u} d={d}");
            }
        }
    }

    #[test]
    fn fft_matches_direct() {
        for (u, d) in [(1usize, 1usize), (2, 2), (4, 3), (32, 16), (256, 8)] {
            let plan = Plan::new(2 * u);
            let y = rand_vec(u * d, 3);
            let rho = rand_vec(2 * u * d, 4);
            let (sre, sim) = spectrum_planes(&plan, &rho, d);
            let mut scratch = TileScratch::default();
            let mut got = vec![0.0f32; u * d];
            tile_conv_fft_into(&plan, &y, &sre, &sim, &mut got, &mut scratch, d);
            let want = naive_tile(&y, &rho, u, d);
            let tol = 1e-3 * (u as f32).sqrt();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < tol, "u={u} d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fft_accumulates_rather_than_overwrites() {
        let (u, d) = (4usize, 2usize);
        let plan = Plan::new(2 * u);
        let y = rand_vec(u * d, 5);
        let rho = rand_vec(2 * u * d, 6);
        let (sre, sim) = spectrum_planes(&plan, &rho, d);
        let mut scratch = TileScratch::default();
        let mut out = vec![10.0f32; u * d];
        tile_conv_fft_into(&plan, &y, &sre, &sim, &mut out, &mut scratch, d);
        let want = naive_tile(&y, &rho, u, d);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - 10.0 - b).abs() < 1e-3);
        }
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // second call must not see residue from the first
        let (u, d) = (8usize, 3usize);
        let plan = Plan::new(2 * u);
        let mut scratch = TileScratch::with_capacity(2 * u, d);
        let rho = rand_vec(2 * u * d, 7);
        let (sre, sim) = spectrum_planes(&plan, &rho, d);
        let y1 = rand_vec(u * d, 8);
        let y2 = rand_vec(u * d, 9);
        let mut out_a = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan, &y1, &sre, &sim, &mut out_a, &mut scratch, d);
        let mut out_b = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan, &y2, &sre, &sim, &mut out_b, &mut scratch, d);
        let mut fresh = TileScratch::default();
        let mut out_c = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan, &y2, &sre, &sim, &mut out_c, &mut fresh, d);
        for (b, c) in out_b.iter().zip(&out_c) {
            assert_eq!(b, c);
        }
    }

    #[test]
    fn rfft_matches_direct() {
        // acceptance: within 1e-3·√U of the direct reference at mixed D
        for (u, d) in [(1usize, 1usize), (2, 2), (4, 3), (32, 16), (256, 8), (64, 1), (16, 64)] {
            let plan = RfftPlan::new(2 * u);
            let y = rand_vec(u * d, 30 + u as u64);
            let rho = rand_vec(2 * u * d, 31 + u as u64);
            let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
            let mut scratch = TileScratch::default();
            let mut got = vec![0.0f32; u * d];
            tile_conv_rfft_into(&plan, &y, &sre, &sim, &mut got, &mut scratch, d);
            let want = naive_tile(&y, &rho, u, d);
            let tol = 1e-3 * (u as f32).sqrt();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < tol, "u={u} d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rfft_matches_complex_fft_kernel() {
        // the two FFT pipelines are the same math; agree to FP rounding
        for (u, d) in [(4usize, 5usize), (64, 8), (256, 4)] {
            let y = rand_vec(u * d, 40);
            let rho = rand_vec(2 * u * d, 41);
            let mut scratch = TileScratch::default();

            let plan_c = Plan::new(2 * u);
            let (fre, fim) = spectrum_planes(&plan_c, &rho, d);
            let mut out_c = vec![0.0f32; u * d];
            tile_conv_fft_into(&plan_c, &y, &fre, &fim, &mut out_c, &mut scratch, d);

            let plan_r = RfftPlan::new(2 * u);
            let (hre, him) = rfft::spectrum_halfplanes(&plan_r, &rho, d);
            let mut out_r = vec![0.0f32; u * d];
            tile_conv_rfft_into(&plan_r, &y, &hre, &him, &mut out_r, &mut scratch, d);

            let tol = 1e-3 * (u as f32).sqrt();
            for (a, b) in out_r.iter().zip(&out_c) {
                assert!((a - b).abs() < tol, "u={u} d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rfft_accumulates_rather_than_overwrites() {
        let (u, d) = (8usize, 3usize);
        let plan = RfftPlan::new(2 * u);
        let y = rand_vec(u * d, 50);
        let rho = rand_vec(2 * u * d, 51);
        let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
        let mut scratch = TileScratch::default();
        let mut out = vec![-3.0f32; u * d];
        tile_conv_rfft_into(&plan, &y, &sre, &sim, &mut out, &mut scratch, d);
        let want = naive_tile(&y, &rho, u, d);
        for (a, b) in out.iter().zip(&want) {
            assert!((a + 3.0 - b).abs() < 1e-3);
        }
    }

    #[test]
    fn rfft_scratch_reuse_is_clean() {
        // second call (and a call after the complex path used the same
        // scratch) must not see residue
        let (u, d) = (16usize, 2usize);
        let plan = RfftPlan::new(2 * u);
        let plan_c = Plan::new(2 * u);
        let rho = rand_vec(2 * u * d, 60);
        let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
        let (fre, fim) = spectrum_planes(&plan_c, &rho, d);
        let y1 = rand_vec(u * d, 61);
        let y2 = rand_vec(u * d, 62);

        let mut scratch = TileScratch::with_capacity(2 * u, d);
        let mut out_a = vec![0.0f32; u * d];
        tile_conv_rfft_into(&plan, &y1, &sre, &sim, &mut out_a, &mut scratch, d);
        let mut out_x = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan_c, &y1, &fre, &fim, &mut out_x, &mut scratch, d);
        let mut out_b = vec![0.0f32; u * d];
        tile_conv_rfft_into(&plan, &y2, &sre, &sim, &mut out_b, &mut scratch, d);

        let mut fresh = TileScratch::default();
        let mut out_c = vec![0.0f32; u * d];
        tile_conv_rfft_into(&plan, &y2, &sre, &sim, &mut out_c, &mut fresh, d);
        for (b, c) in out_b.iter().zip(&out_c) {
            assert_eq!(b, c);
        }
    }

    #[test]
    fn spectrum_planes_zero_pads() {
        let plan = Plan::new(8);
        let d = 2;
        let seg = rand_vec(3 * d, 10); // only 3 of 8 rows provided
        let (re, _im) = spectrum_planes(&plan, &seg, d);
        // DC bin equals the sum of the provided rows per lane
        for lane in 0..d {
            let want: f32 = (0..3).map(|t| seg[t * d + lane]).sum();
            assert!((re[lane] - want).abs() < 1e-4);
        }
    }
}
