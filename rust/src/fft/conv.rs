//! FFT tile convolution (Lemma 1 + Appendix C) on the vectorized FFT.
//!
//! The tile at iteration i contributes streams[i-U+1..i] to pending
//! [i+1..i+U]. Appendix C shows one *cyclic* convolution of order 2U
//! suffices (the wrap-around lands outside the kept slice), and that the
//! filter-prefix spectrum can be precomputed per (layer, U) — dropping the
//! per-tile cost from 3 DFTs to 2.
//!
//! Three pipelines implement the same tile: [`tile_conv_fft_into`] on full
//! complex spectra (the original kernel, kept as the comparison baseline),
//! [`tile_conv_rfft_into`] on real-input half-spectra (packed transforms of
//! order U, U+1 cached filter bins — see `fft::rfft`), and
//! [`tile_conv_rfft_fused_into`] — the hot path — which runs the whole
//! pack→rfft→cmul→irfft→accumulate chain per D-block over a
//! [`BlockedSpectrum`] filter so the half-spectrum never materializes in
//! `TileScratch` (the Flash-Attention lesson: bytes moved, not FLOPs).

use super::plan::Plan;
use super::rfft::{self, RfftPlan};
use super::simd;
use super::vecfft;

/// Reusable scratch planes for tile convolutions (sized to the largest
/// tile at engine init; no allocation on the token loop).
///
/// The complex path uses the `re`/`im` pair at the full transform order n;
/// the rfft path reuses the same pair at order n/2 for the packed
/// transform and adds a half-spectrum pair of n/2 + 1 bins.
#[derive(Debug, Default)]
pub struct TileScratch {
    re: Vec<f32>,
    im: Vec<f32>,
    half_re: Vec<f32>,
    half_im: Vec<f32>,
}

impl TileScratch {
    pub fn with_capacity(max_n: usize, d: usize) -> TileScratch {
        TileScratch {
            re: vec![0.0; max_n * d],
            im: vec![0.0; max_n * d],
            half_re: vec![0.0; (max_n / 2 + 1) * d],
            half_im: vec![0.0; (max_n / 2 + 1) * d],
        }
    }

    fn planes(&mut self, n: usize, d: usize) -> (&mut [f32], &mut [f32]) {
        let len = n * d;
        if self.re.len() < len {
            self.re.resize(len, 0.0);
            self.im.resize(len, 0.0);
        }
        (&mut self.re[..len], &mut self.im[..len])
    }

    /// Packed (`[n/2][d]`) + half-spectrum (`[n/2+1][d]`) planes for the
    /// rfft pipeline at transform order `n`.
    #[allow(clippy::type_complexity)]
    fn rfft_planes(
        &mut self,
        n: usize,
        d: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32], &mut [f32]) {
        let zlen = (n / 2) * d;
        let xlen = (n / 2 + 1) * d;
        if self.re.len() < zlen {
            self.re.resize(zlen, 0.0);
            self.im.resize(zlen, 0.0);
        }
        if self.half_re.len() < xlen {
            self.half_re.resize(xlen, 0.0);
            self.half_im.resize(xlen, 0.0);
        }
        (
            &mut self.re[..zlen],
            &mut self.im[..zlen],
            &mut self.half_re[..xlen],
            &mut self.half_im[..xlen],
        )
    }

    /// Scratch for the fused kernel at packed order `m` over one lane
    /// block of width `bd`: packed `[m][bd]` planes plus two pair-temp
    /// rows per plane (`X[k]`/`X[m-k]` live in registers-adjacent temps,
    /// never as full half-spectrum planes).
    #[allow(clippy::type_complexity)]
    fn fused_planes(
        &mut self,
        m: usize,
        bd: usize,
    ) -> (&mut [f32], &mut [f32], &mut [f32], &mut [f32]) {
        let zlen = m * bd;
        let tlen = 2 * bd;
        if self.re.len() < zlen {
            self.re.resize(zlen, 0.0);
            self.im.resize(zlen, 0.0);
        }
        if self.half_re.len() < tlen {
            self.half_re.resize(tlen, 0.0);
            self.half_im.resize(tlen, 0.0);
        }
        (
            &mut self.re[..zlen],
            &mut self.im[..zlen],
            &mut self.half_re[..tlen],
            &mut self.half_im[..tlen],
        )
    }
}

/// Measured-default lane-block width of the fused rfft kernel. The
/// per-block working set is `2·U·bd` packed floats plus 4 temp rows — at
/// U = 256 and bd = 16 that is ~64 KiB, L1/L2-resident where the unfused
/// whole-width planes (D = 64: ~512 KiB with the half-spectrum pair) are
/// not. 16 lanes is also two AVX2 vectors / four NEON vectors, so every
/// row op runs tail-free on both targets.
///
/// The width actually used is resolved per process by
/// [`simd::fused_block_d`], which probes the L1d size from the sysfs
/// cache topology and falls back to this constant when the hierarchy is
/// unreadable (`FI_FUSED_BLOCK_D` overrides both). Each
/// [`BlockedSpectrum`] captures the width it was built with, so a
/// mid-process override cannot desynchronize layout and iteration.
pub const FUSED_BLOCK_D: usize = 16;

/// Filter-prefix half-spectrum re-laid for the fused kernel: the D lanes
/// are split into blocks of ≤ [`FUSED_BLOCK_D`], each block holding its
/// `U+1` bins contiguously (`[nblocks][bins][bd]`). The fused per-block
/// pass then streams the filter sequentially instead of striding through
/// `[bins][D]` rows at a `D`-lane pitch — this is the blocked layout the
/// EXPERIMENTS.md §2 D-blocking experiment lacked (it blocked the loops
/// but kept the flat layout, so every block walk still paid full-row
/// cache lines).
///
/// Same total memory as the flat half-planes; [`Self::to_halfplanes`]
/// reconstructs the flat `[bins][D]` layout for the PJRT
/// `@rho_re/@rho_im` uploads.
#[derive(Debug)]
pub struct BlockedSpectrum {
    re: Vec<f32>,
    im: Vec<f32>,
    d: usize,
    bins: usize,
    /// Block width this spectrum was laid out with (frozen at build time
    /// so layout and iteration can never disagree).
    bd: usize,
}

impl BlockedSpectrum {
    /// Re-block flat `[bins][d]` half-spectrum planes at the
    /// cache-adapted width from [`simd::fused_block_d`].
    pub fn from_halfplanes(re: &[f32], im: &[f32], d: usize) -> BlockedSpectrum {
        Self::from_halfplanes_with(re, im, d, simd::fused_block_d())
    }

    /// Re-block at an explicit width (tests and width experiments).
    pub fn from_halfplanes_with(
        re: &[f32],
        im: &[f32],
        d: usize,
        block_d: usize,
    ) -> BlockedSpectrum {
        assert!(d > 0 && re.len() % d == 0, "plane len {} not a multiple of d={d}", re.len());
        assert_eq!(re.len(), im.len());
        assert!(block_d > 0, "block width must be positive");
        let bins = re.len() / d;
        let mut bre = Vec::with_capacity(re.len());
        let mut bim = Vec::with_capacity(im.len());
        for t0 in (0..d).step_by(block_d) {
            let bd = (d - t0).min(block_d);
            for k in 0..bins {
                bre.extend_from_slice(&re[k * d + t0..k * d + t0 + bd]);
                bim.extend_from_slice(&im[k * d + t0..k * d + t0 + bd]);
            }
        }
        BlockedSpectrum { re: bre, im: bim, d, bins, bd: block_d }
    }

    /// The block width this spectrum was laid out with.
    pub fn block_d(&self) -> usize {
        self.bd
    }

    /// Number of half-spectrum bins per lane (U + 1).
    pub fn bins(&self) -> usize {
        self.bins
    }

    /// Total lane count D.
    pub fn d(&self) -> usize {
        self.d
    }

    pub fn num_blocks(&self) -> usize {
        self.d.div_ceil(self.bd)
    }

    /// `(lane offset, block width)` of block `blk`.
    pub fn block_geom(&self, blk: usize) -> (usize, usize) {
        let t0 = blk * self.bd;
        (t0, (self.d - t0).min(self.bd))
    }

    /// The `[bins][bd]` re/im planes of block `blk`.
    pub fn block(&self, blk: usize) -> (&[f32], &[f32]) {
        let (t0, bd) = self.block_geom(blk);
        let start = t0 * self.bins; // blocks are packed in lane order
        let len = self.bins * bd;
        (&self.re[start..start + len], &self.im[start..start + len])
    }

    /// Reconstruct the flat `[bins][D]` half-planes (the PJRT
    /// `@rho_re/@rho_im` buffer layout).
    pub fn to_halfplanes(&self) -> (Vec<f32>, Vec<f32>) {
        let mut re = vec![0.0f32; self.bins * self.d];
        let mut im = vec![0.0f32; self.bins * self.d];
        for blk in 0..self.num_blocks() {
            let (t0, bd) = self.block_geom(blk);
            let (bre, bim) = self.block(blk);
            for k in 0..self.bins {
                re[k * self.d + t0..k * self.d + t0 + bd]
                    .copy_from_slice(&bre[k * bd..(k + 1) * bd]);
                im[k * self.d + t0..k * self.d + t0 + bd]
                    .copy_from_slice(&bim[k * bd..(k + 1) * bd]);
            }
        }
        (re, im)
    }
}

/// Precompute the spectrum planes of a real filter segment.
///
/// `seg` is `[m][d]` (m <= plan.n; zero-padded). Returns `([n][d], [n][d])`
/// re/im planes of its order-n DFT — the layout both the native path and
/// the `tau_fft` PJRT artifacts consume (artifacts take bins `[0, n/2]`).
pub fn spectrum_planes(plan: &Plan, seg: &[f32], d: usize) -> (Vec<f32>, Vec<f32>) {
    let n = plan.n;
    assert!(seg.len() <= n * d && seg.len() % d == 0);
    let mut re = vec![0.0f32; n * d];
    let mut im = vec![0.0f32; n * d];
    re[..seg.len()].copy_from_slice(seg);
    vecfft::forward(plan, &mut re, &mut im, d);
    (re, im)
}

/// FFT tile: `out_add[k][:] += sum_j y[j][:] * rho[U+k-j][:]` using the
/// precomputed filter spectrum.
///
/// * `plan`    — order-2U plan.
/// * `y`       — `[U][d]` contiguous tile input.
/// * `spec_*`  — `[2U][d]` filter-prefix spectrum planes.
/// * `out_add` — `[U][d]`; the middle-U slice of the cyclic convolution is
///   accumulated into it (the paper aggregates tiles in place, §3.3).
///
/// PERF NOTE: a D-blocked (cache-tiled) variant was measured at
/// BLOCK_D ∈ {8, 16, 32} and was neutral-to-worse on this machine (the
/// [2U][D] working set already streams well at D = 64; see EXPERIMENTS.md
/// §Perf iteration log), so the simple whole-width path is kept.
pub fn tile_conv_fft_into(
    plan: &Plan,
    y: &[f32],
    spec_re: &[f32],
    spec_im: &[f32],
    out_add: &mut [f32],
    scratch: &mut TileScratch,
    d: usize,
) {
    let n = plan.n;
    let u = n / 2;
    debug_assert_eq!(y.len(), u * d);
    debug_assert_eq!(spec_re.len(), n * d);
    debug_assert_eq!(out_add.len(), u * d);

    let (re, im) = scratch.planes(n, d);
    re[..u * d].copy_from_slice(y);
    re[u * d..].fill(0.0);
    im.fill(0.0);

    vecfft::forward(plan, re, im, d);
    vecfft::cmul_inplace(re, im, spec_re, spec_im);
    vecfft::inverse_unscaled(plan, re, im, d);

    // keep rows [U, 2U), fold in the 1/n inverse scale during accumulation
    let s = 1.0 / n as f32;
    let tail = &re[u * d..n * d];
    for (o, v) in out_add.iter_mut().zip(tail) {
        *o += v * s;
    }
}

/// Rfft tile: same contract as [`tile_conv_fft_into`] but on the real-input
/// half-spectrum pipeline — the native τ hot path.
///
/// * `plan`    — rfft plan of real order 2U.
/// * `y`       — `[U][d]` contiguous tile input (real; zero-padded to 2U).
/// * `spec_*`  — `[(U+1)][d]` filter-prefix *half*-spectrum planes
///   (bins [0, U] of the order-2U DFT; see [`rfft::spectrum_halfplanes`]).
/// * `out_add` — `[U][d]`; the middle-U slice of the order-2U cyclic
///   convolution is accumulated into it, 1/n folded into the accumulation.
///
/// Both packed transforms run at order U instead of 2U and the pointwise
/// product touches U+1 bins instead of 2U — roughly half the FLOPs and
/// scratch traffic of the complex path, with identical results up to
/// rounding (proven against `tile_conv_direct_into` in the tests below).
pub fn tile_conv_rfft_into(
    plan: &RfftPlan,
    y: &[f32],
    spec_re: &[f32],
    spec_im: &[f32],
    out_add: &mut [f32],
    scratch: &mut TileScratch,
    d: usize,
) {
    let n = plan.n;
    let u = n / 2;
    debug_assert_eq!(y.len(), u * d);
    debug_assert_eq!(spec_re.len(), (u + 1) * d);
    debug_assert_eq!(spec_im.len(), (u + 1) * d);
    debug_assert_eq!(out_add.len(), u * d);

    let (zre, zim, xre, xim) = scratch.rfft_planes(n, d);
    rfft::rfft_into(plan, y, xre, xim, zre, zim, d);
    rfft::cmul_halfspec_inplace(xre, xim, spec_re, spec_im);
    rfft::irfft_packed_unscaled(plan, xre, xim, zre, zim, d);

    // keep rows [U, 2U) of the (n-scaled) cyclic convolution; the packed
    // layout interleaves them as zre[k] = n·x[2k], zim[k] = n·x[2k+1].
    let s = 1.0 / n as f32;
    if u == 1 {
        // the single kept row (t = 1) is odd: it lives in the im plane
        for t in 0..d {
            out_add[t] += zim[t] * s;
        }
    } else {
        for k in u / 2..u {
            let r0 = (2 * k - u) * d; // even kept row ← re plane
            for t in 0..d {
                out_add[r0 + t] += zre[k * d + t] * s;
                out_add[r0 + d + t] += zim[k * d + t] * s;
            }
        }
    }
}

/// Fused rfft tile — the native τ hot path. Same contract as
/// [`tile_conv_rfft_into`] but the whole pack→rfft→cmul→irfft→accumulate
/// chain runs per lane block of ≤ [`FUSED_BLOCK_D`] lanes against a
/// [`BlockedSpectrum`] filter, and the half-spectrum is never stored:
/// each conjugate bin pair `(k, m-k)` is unpacked into four temp rows,
/// multiplied by the filter bins, and repacked straight back into the
/// packed planes. Versus [`tile_conv_rfft_into`] this removes the
/// `[(U+1)][D]` half-spectrum round-trip through `TileScratch` (≈ half
/// the scratch traffic) and shrinks the resident working set from
/// `O(U·D)` to `O(U·FUSED_BLOCK_D)` — see `tiling::flops` for the model.
///
/// Bit-exactness: every per-lane arithmetic expression is identical to
/// the unfused pipeline (same primitives from `fft::simd`, same
/// association, no FMA), and lane blocking never reorders a lane's op
/// sequence — so results equal [`tile_conv_rfft_into`]'s *bit-for-bit*,
/// which the tests below assert with `assert_eq!`.
pub fn tile_conv_rfft_fused_into(
    plan: &RfftPlan,
    y: &[f32],
    spec: &BlockedSpectrum,
    out_add: &mut [f32],
    scratch: &mut TileScratch,
    d: usize,
) {
    let n = plan.n;
    let u = n / 2;
    let m = plan.m; // == u
    debug_assert_eq!(y.len(), u * d);
    debug_assert_eq!(spec.d(), d);
    debug_assert_eq!(spec.bins(), m + 1);
    debug_assert_eq!(out_add.len(), u * d);
    let s = 1.0 / n as f32;
    let rows = u; // provided input rows; [U, 2U) is the logical zero-pad

    for blk in 0..spec.num_blocks() {
        let (t0, bd) = spec.block_geom(blk);
        let (zre, zim, tp_re, tp_im) = scratch.fused_planes(m, bd);

        // pack this lane block: z[k] = x[2k] + i·x[2k+1], zero-padded
        for k in 0..m {
            let (even, odd) = (2 * k, 2 * k + 1);
            let zr = &mut zre[k * bd..(k + 1) * bd];
            if even < rows {
                zr.copy_from_slice(&y[even * d + t0..even * d + t0 + bd]);
            } else {
                zr.fill(0.0);
            }
            let zi = &mut zim[k * bd..(k + 1) * bd];
            if odd < rows {
                zi.copy_from_slice(&y[odd * d + t0..odd * d + t0 + bd]);
            } else {
                zi.fill(0.0);
            }
        }

        vecfft::forward(&plan.half, zre, zim, bd);

        let (bre, bim) = spec.block(blk);

        // endpoint bins (0, m): both come from Z[0]; X'[0] and X'[m]
        // meet again in the repack of Z'[0] (the k = 0 pair)
        {
            let (xk_re, xj_re) = tp_re.split_at_mut(bd);
            let (xk_im, xj_im) = tp_im.split_at_mut(bd);
            simd::rfft_endpoints_row(xk_re, xk_im, xj_re, xj_im, &zre[..bd], &zim[..bd]);
            simd::cmul_rows(xk_re, xk_im, &bre[..bd], &bim[..bd]);
            simd::cmul_rows(xj_re, xj_im, &bre[m * bd..(m + 1) * bd], &bim[m * bd..(m + 1) * bd]);
            simd::irfft_repack_row(
                &mut zre[..bd],
                &mut zim[..bd],
                xk_re,
                xk_im,
                xj_re,
                xj_im,
                plan.tw_re[0],
                plan.tw_im[0],
            );
        }

        // conjugate bin pairs (k, j = m-k), k ∈ [1, m/2): unpack both
        // from Z, multiply, repack both — Z rows k and j are each read
        // before either is overwritten
        for k in 1..=(m.saturating_sub(1)) / 2 {
            let j = m - k;
            let (xk_re, xj_re) = tp_re.split_at_mut(bd);
            let (xk_im, xj_im) = tp_im.split_at_mut(bd);
            simd::rfft_unpack_row(
                xk_re,
                xk_im,
                &zre[k * bd..(k + 1) * bd],
                &zim[k * bd..(k + 1) * bd],
                &zre[j * bd..(j + 1) * bd],
                &zim[j * bd..(j + 1) * bd],
                plan.tw_re[k],
                plan.tw_im[k],
            );
            simd::rfft_unpack_row(
                xj_re,
                xj_im,
                &zre[j * bd..(j + 1) * bd],
                &zim[j * bd..(j + 1) * bd],
                &zre[k * bd..(k + 1) * bd],
                &zim[k * bd..(k + 1) * bd],
                plan.tw_re[j],
                plan.tw_im[j],
            );
            simd::cmul_rows(xk_re, xk_im, &bre[k * bd..(k + 1) * bd], &bim[k * bd..(k + 1) * bd]);
            simd::cmul_rows(xj_re, xj_im, &bre[j * bd..(j + 1) * bd], &bim[j * bd..(j + 1) * bd]);
            simd::irfft_repack_row(
                &mut zre[k * bd..(k + 1) * bd],
                &mut zim[k * bd..(k + 1) * bd],
                xk_re,
                xk_im,
                xj_re,
                xj_im,
                plan.tw_re[k],
                plan.tw_im[k],
            );
            simd::irfft_repack_row(
                &mut zre[j * bd..(j + 1) * bd],
                &mut zim[j * bd..(j + 1) * bd],
                xj_re,
                xj_im,
                xk_re,
                xk_im,
                plan.tw_re[j],
                plan.tw_im[j],
            );
        }

        // self-paired middle bin k = m/2 (m even): j == k
        if m >= 2 && m % 2 == 0 {
            let k = m / 2;
            let (xk_re, _) = tp_re.split_at_mut(bd);
            let (xk_im, _) = tp_im.split_at_mut(bd);
            simd::rfft_unpack_row(
                xk_re,
                xk_im,
                &zre[k * bd..(k + 1) * bd],
                &zim[k * bd..(k + 1) * bd],
                &zre[k * bd..(k + 1) * bd],
                &zim[k * bd..(k + 1) * bd],
                plan.tw_re[k],
                plan.tw_im[k],
            );
            simd::cmul_rows(xk_re, xk_im, &bre[k * bd..(k + 1) * bd], &bim[k * bd..(k + 1) * bd]);
            simd::irfft_repack_row(
                &mut zre[k * bd..(k + 1) * bd],
                &mut zim[k * bd..(k + 1) * bd],
                xk_re,
                xk_im,
                xk_re,
                xk_im,
                plan.tw_re[k],
                plan.tw_im[k],
            );
        }

        vecfft::inverse_unscaled(&plan.half, zre, zim, bd);

        // keep rows [U, 2U), 1/n folded into the accumulate (packed
        // layout: zre[k] = n·x[2k], zim[k] = n·x[2k+1])
        if u == 1 {
            // the single kept row (t = 1) is odd: it lives in the im plane
            simd::acc_scaled(&mut out_add[t0..t0 + bd], &zim[..bd], s);
        } else {
            for k in u / 2..u {
                let r0 = (2 * k - u) * d + t0; // even kept row ← re plane
                simd::acc_scaled(&mut out_add[r0..r0 + bd], &zre[k * bd..(k + 1) * bd], s);
                simd::acc_scaled(&mut out_add[r0 + d..r0 + d + bd], &zim[k * bd..(k + 1) * bd], s);
            }
        }
    }
}

/// O(U^2 d) reference tile (also the core of the `rust_direct` tau impl):
/// `out_add[k][:] += sum_j y[j][:] * rho_seg[U+k-j][:]`.
pub fn tile_conv_direct_into(y: &[f32], rho_seg: &[f32], out_add: &mut [f32], d: usize) {
    let u = y.len() / d;
    debug_assert_eq!(y.len(), u * d);
    debug_assert_eq!(rho_seg.len(), 2 * u * d);
    debug_assert_eq!(out_add.len(), u * d);
    // loop order: j outer so both rho rows and out rows stream contiguously
    for j in 0..u {
        let yj = &y[j * d..(j + 1) * d];
        // out[k] += yj * rho[U + k - j], k = 0..U  => rho rows U-j .. 2U-j
        let rho_base = (u - j) * d;
        for k in 0..u {
            let r = &rho_seg[rho_base + k * d..rho_base + (k + 1) * d];
            let o = &mut out_add[k * d..(k + 1) * d];
            for t in 0..d {
                o[t] += yj[t] * r[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_vec(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    fn naive_tile(y: &[f32], rho: &[f32], u: usize, d: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; u * d];
        for k in 0..u {
            for j in 0..u {
                let lag = u + k - j;
                for t in 0..d {
                    out[k * d + t] += y[j * d + t] * rho[lag * d + t];
                }
            }
        }
        out
    }

    #[test]
    fn direct_matches_naive() {
        for (u, d) in [(1usize, 1usize), (2, 3), (8, 4), (16, 64)] {
            let y = rand_vec(u * d, 1);
            let rho = rand_vec(2 * u * d, 2);
            let mut out = vec![0.0f32; u * d];
            tile_conv_direct_into(&y, &rho, &mut out, d);
            let want = naive_tile(&y, &rho, u, d);
            for (a, b) in out.iter().zip(&want) {
                assert!((a - b).abs() < 1e-4, "u={u} d={d}");
            }
        }
    }

    #[test]
    fn fft_matches_direct() {
        for (u, d) in [(1usize, 1usize), (2, 2), (4, 3), (32, 16), (256, 8)] {
            let plan = Plan::new(2 * u);
            let y = rand_vec(u * d, 3);
            let rho = rand_vec(2 * u * d, 4);
            let (sre, sim) = spectrum_planes(&plan, &rho, d);
            let mut scratch = TileScratch::default();
            let mut got = vec![0.0f32; u * d];
            tile_conv_fft_into(&plan, &y, &sre, &sim, &mut got, &mut scratch, d);
            let want = naive_tile(&y, &rho, u, d);
            let tol = 1e-3 * (u as f32).sqrt();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < tol, "u={u} d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn fft_accumulates_rather_than_overwrites() {
        let (u, d) = (4usize, 2usize);
        let plan = Plan::new(2 * u);
        let y = rand_vec(u * d, 5);
        let rho = rand_vec(2 * u * d, 6);
        let (sre, sim) = spectrum_planes(&plan, &rho, d);
        let mut scratch = TileScratch::default();
        let mut out = vec![10.0f32; u * d];
        tile_conv_fft_into(&plan, &y, &sre, &sim, &mut out, &mut scratch, d);
        let want = naive_tile(&y, &rho, u, d);
        for (a, b) in out.iter().zip(&want) {
            assert!((a - 10.0 - b).abs() < 1e-3);
        }
    }

    #[test]
    fn scratch_reuse_is_clean() {
        // second call must not see residue from the first
        let (u, d) = (8usize, 3usize);
        let plan = Plan::new(2 * u);
        let mut scratch = TileScratch::with_capacity(2 * u, d);
        let rho = rand_vec(2 * u * d, 7);
        let (sre, sim) = spectrum_planes(&plan, &rho, d);
        let y1 = rand_vec(u * d, 8);
        let y2 = rand_vec(u * d, 9);
        let mut out_a = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan, &y1, &sre, &sim, &mut out_a, &mut scratch, d);
        let mut out_b = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan, &y2, &sre, &sim, &mut out_b, &mut scratch, d);
        let mut fresh = TileScratch::default();
        let mut out_c = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan, &y2, &sre, &sim, &mut out_c, &mut fresh, d);
        for (b, c) in out_b.iter().zip(&out_c) {
            assert_eq!(b, c);
        }
    }

    #[test]
    fn rfft_matches_direct() {
        // acceptance: within 1e-3·√U of the direct reference at mixed D
        for (u, d) in [(1usize, 1usize), (2, 2), (4, 3), (32, 16), (256, 8), (64, 1), (16, 64)] {
            let plan = RfftPlan::new(2 * u);
            let y = rand_vec(u * d, 30 + u as u64);
            let rho = rand_vec(2 * u * d, 31 + u as u64);
            let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
            let mut scratch = TileScratch::default();
            let mut got = vec![0.0f32; u * d];
            tile_conv_rfft_into(&plan, &y, &sre, &sim, &mut got, &mut scratch, d);
            let want = naive_tile(&y, &rho, u, d);
            let tol = 1e-3 * (u as f32).sqrt();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < tol, "u={u} d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rfft_matches_complex_fft_kernel() {
        // the two FFT pipelines are the same math; agree to FP rounding
        for (u, d) in [(4usize, 5usize), (64, 8), (256, 4)] {
            let y = rand_vec(u * d, 40);
            let rho = rand_vec(2 * u * d, 41);
            let mut scratch = TileScratch::default();

            let plan_c = Plan::new(2 * u);
            let (fre, fim) = spectrum_planes(&plan_c, &rho, d);
            let mut out_c = vec![0.0f32; u * d];
            tile_conv_fft_into(&plan_c, &y, &fre, &fim, &mut out_c, &mut scratch, d);

            let plan_r = RfftPlan::new(2 * u);
            let (hre, him) = rfft::spectrum_halfplanes(&plan_r, &rho, d);
            let mut out_r = vec![0.0f32; u * d];
            tile_conv_rfft_into(&plan_r, &y, &hre, &him, &mut out_r, &mut scratch, d);

            let tol = 1e-3 * (u as f32).sqrt();
            for (a, b) in out_r.iter().zip(&out_c) {
                assert!((a - b).abs() < tol, "u={u} d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn rfft_accumulates_rather_than_overwrites() {
        let (u, d) = (8usize, 3usize);
        let plan = RfftPlan::new(2 * u);
        let y = rand_vec(u * d, 50);
        let rho = rand_vec(2 * u * d, 51);
        let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
        let mut scratch = TileScratch::default();
        let mut out = vec![-3.0f32; u * d];
        tile_conv_rfft_into(&plan, &y, &sre, &sim, &mut out, &mut scratch, d);
        let want = naive_tile(&y, &rho, u, d);
        for (a, b) in out.iter().zip(&want) {
            assert!((a + 3.0 - b).abs() < 1e-3);
        }
    }

    #[test]
    fn rfft_scratch_reuse_is_clean() {
        // second call (and a call after the complex path used the same
        // scratch) must not see residue
        let (u, d) = (16usize, 2usize);
        let plan = RfftPlan::new(2 * u);
        let plan_c = Plan::new(2 * u);
        let rho = rand_vec(2 * u * d, 60);
        let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
        let (fre, fim) = spectrum_planes(&plan_c, &rho, d);
        let y1 = rand_vec(u * d, 61);
        let y2 = rand_vec(u * d, 62);

        let mut scratch = TileScratch::with_capacity(2 * u, d);
        let mut out_a = vec![0.0f32; u * d];
        tile_conv_rfft_into(&plan, &y1, &sre, &sim, &mut out_a, &mut scratch, d);
        let mut out_x = vec![0.0f32; u * d];
        tile_conv_fft_into(&plan_c, &y1, &fre, &fim, &mut out_x, &mut scratch, d);
        let mut out_b = vec![0.0f32; u * d];
        tile_conv_rfft_into(&plan, &y2, &sre, &sim, &mut out_b, &mut scratch, d);

        let mut fresh = TileScratch::default();
        let mut out_c = vec![0.0f32; u * d];
        tile_conv_rfft_into(&plan, &y2, &sre, &sim, &mut out_c, &mut fresh, d);
        for (b, c) in out_b.iter().zip(&out_c) {
            assert_eq!(b, c);
        }
    }

    /// Satellite gate: the fused kernel must be *bit-identical* to the
    /// unfused rfft pipeline (which itself dispatches through fft::simd,
    /// so with `--features simd` this also pins SIMD == scalar shapes):
    /// same per-lane expressions, no FMA, blocking never reorders a
    /// lane. Covers the ISSUE grid — U ∈ {1, 2, 4, 32, 256}, odd D,
    /// tail lanes < vector width, D straddling FUSED_BLOCK_D.
    #[test]
    fn fused_matches_unfused_bitexact() {
        for (u, d) in [
            (1usize, 1usize),
            (1, 5),
            (2, 3),
            (4, 7),
            (4, 16),
            (32, 17),
            (32, 33),
            (256, 8),
            (16, 64),
        ] {
            let plan = RfftPlan::new(2 * u);
            let y = rand_vec(u * d, 70 + (u + d) as u64);
            let rho = rand_vec(2 * u * d, 71 + (u + d) as u64);
            let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);

            let mut scratch = TileScratch::default();
            let mut out_ref = vec![0.5f32; u * d];
            tile_conv_rfft_into(&plan, &y, &sre, &sim, &mut out_ref, &mut scratch, d);

            let spec = BlockedSpectrum::from_halfplanes(&sre, &sim, d);
            let mut out_fused = vec![0.5f32; u * d];
            tile_conv_rfft_fused_into(&plan, &y, &spec, &mut out_fused, &mut scratch, d);

            for (i, (a, b)) in out_fused.iter().zip(&out_ref).enumerate() {
                assert_eq!(
                    a.to_bits(),
                    b.to_bits(),
                    "u={u} d={d} i={i}: fused {a} != unfused {b}"
                );
            }
        }
    }

    #[test]
    fn fused_matches_direct() {
        for (u, d) in [(1usize, 1usize), (2, 2), (4, 3), (32, 16), (256, 8), (64, 1), (16, 64)] {
            let plan = RfftPlan::new(2 * u);
            let y = rand_vec(u * d, 80 + u as u64);
            let rho = rand_vec(2 * u * d, 81 + u as u64);
            let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
            let spec = BlockedSpectrum::from_halfplanes(&sre, &sim, d);
            let mut scratch = TileScratch::default();
            let mut got = vec![0.0f32; u * d];
            tile_conv_rfft_fused_into(&plan, &y, &spec, &mut got, &mut scratch, d);
            let want = naive_tile(&y, &rho, u, d);
            let tol = 1e-3 * (u as f32).sqrt();
            for (a, b) in got.iter().zip(&want) {
                assert!((a - b).abs() < tol, "u={u} d={d}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn blocked_spectrum_roundtrips_to_halfplanes() {
        // the PJRT upload path depends on to_halfplanes being exact,
        // whatever block width the cache probe resolved to
        for d in [1usize, 3, 16, 17, 32, 50, 64] {
            let bins = 9;
            let re = rand_vec(bins * d, 90 + d as u64);
            let im = rand_vec(bins * d, 91 + d as u64);
            let spec = BlockedSpectrum::from_halfplanes(&re, &im, d);
            assert_eq!(spec.bins(), bins);
            assert_eq!(spec.num_blocks(), d.div_ceil(spec.block_d()));
            let (rre, rim) = spec.to_halfplanes();
            assert_eq!(rre, re);
            assert_eq!(rim, im);
            // explicit widths (including awkward ones) round-trip too
            for bd in [1usize, 8, 13, 64] {
                let spec = BlockedSpectrum::from_halfplanes_with(&re, &im, d, bd);
                assert_eq!(spec.block_d(), bd);
                assert_eq!(spec.num_blocks(), d.div_ceil(bd));
                let (rre, rim) = spec.to_halfplanes();
                assert_eq!(rre, re, "d={d} bd={bd}");
                assert_eq!(rim, im, "d={d} bd={bd}");
            }
        }
    }

    #[test]
    fn fused_kernel_bitexact_across_block_widths() {
        // the block width changes which lanes share a pass, never the
        // per-lane arithmetic — results must be bit-identical across
        // widths (the invariant that makes the cache probe safe)
        let (u, d) = (32usize, 33usize);
        let plan = RfftPlan::new(2 * u);
        let y = rand_vec(u * d, 120);
        let rho = rand_vec(2 * u * d, 121);
        let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
        let mut reference: Option<Vec<f32>> = None;
        for bd in [1usize, 8, 16, 33, 64] {
            let spec = BlockedSpectrum::from_halfplanes_with(&sre, &sim, d, bd);
            let mut scratch = TileScratch::default();
            let mut out = vec![0.25f32; u * d];
            tile_conv_rfft_fused_into(&plan, &y, &spec, &mut out, &mut scratch, d);
            match &reference {
                None => reference = Some(out),
                Some(want) => {
                    for (i, (a, b)) in out.iter().zip(want).enumerate() {
                        assert_eq!(a.to_bits(), b.to_bits(), "bd={bd} i={i}");
                    }
                }
            }
        }
    }

    #[test]
    fn fused_scratch_reuse_is_clean() {
        // a fused call after unfused/complex calls on the same scratch
        // must not see residue, and vice versa
        let (u, d) = (16usize, 21usize);
        let plan = RfftPlan::new(2 * u);
        let rho = rand_vec(2 * u * d, 95);
        let (sre, sim) = rfft::spectrum_halfplanes(&plan, &rho, d);
        let spec = BlockedSpectrum::from_halfplanes(&sre, &sim, d);
        let y1 = rand_vec(u * d, 96);
        let y2 = rand_vec(u * d, 97);

        let mut scratch = TileScratch::with_capacity(2 * u, d);
        let mut out_a = vec![0.0f32; u * d];
        tile_conv_rfft_fused_into(&plan, &y1, &spec, &mut out_a, &mut scratch, d);
        let mut out_x = vec![0.0f32; u * d];
        tile_conv_rfft_into(&plan, &y1, &sre, &sim, &mut out_x, &mut scratch, d);
        let mut out_b = vec![0.0f32; u * d];
        tile_conv_rfft_fused_into(&plan, &y2, &spec, &mut out_b, &mut scratch, d);

        let mut fresh = TileScratch::default();
        let mut out_c = vec![0.0f32; u * d];
        tile_conv_rfft_fused_into(&plan, &y2, &spec, &mut out_c, &mut fresh, d);
        for (b, c) in out_b.iter().zip(&out_c) {
            assert_eq!(b, c);
        }
    }

    #[test]
    fn spectrum_planes_zero_pads() {
        let plan = Plan::new(8);
        let d = 2;
        let seg = rand_vec(3 * d, 10); // only 3 of 8 rows provided
        let (re, _im) = spectrum_planes(&plan, &seg, d);
        // DC bin equals the sum of the provided rows per lane
        for lane in 0..d {
            let want: f32 = (0..3).map(|t| seg[t * d + lane]).sum();
            assert!((re[lane] - want).abs() < 1e-4);
        }
    }
}
