//! Runtime-dispatched SIMD row primitives for the tau hot path.
//!
//! Every inner loop of the native rfft pipeline (butterflies, the
//! half-spectrum unpack/repack, the pointwise complex multiply, and the
//! scaled accumulate) walks contiguous D-lane rows of SoA `[n][d]`
//! planes. This module lifts those loops into named row primitives with
//! three implementations:
//!
//! - **scalar** — always compiled, the reference semantics. Tier-1 must
//!   stay green with the `simd` cargo feature off, so nothing outside
//!   the dispatch arms is ever `cfg`'d away.
//! - **AVX2** (x86_64, 8 lanes) and **NEON** (aarch64, 4 lanes) —
//!   compiled only under `--features simd`, selected at runtime via
//!   feature detection. On x86_64 the AVX2 path is taken only when
//!   `is_x86_feature_detected!("avx2")` says so; aarch64 always has
//!   NEON. Rows shorter than the vector width, and tail lanes of longer
//!   rows, fall through to the scalar loop.
//!
//! **Bit-exactness contract** (load-bearing — see DESIGN.md §9): the
//! vector paths use only mul/add/sub in *exactly* the same per-lane
//! expression shape as the scalar loop, and never FMA. IEEE-754 makes
//! each lane's result bit-identical to the scalar computation, which is
//! what lets `integration_async` assert bit-identity through the
//! multi-worker executor regardless of feature mode, and what makes the
//! equivalence tests below `assert_eq!` on bits rather than tolerances.
//!
//! Kill-switch: `FI_SIMD=0` (or `off`) forces the scalar backend even
//! when compiled with the feature — the first dispatch caches the
//! decision for the process lifetime.

/// Which implementation the row primitives dispatch to.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Backend {
    Scalar,
    Avx2,
    Neon,
}

/// Resolve the backend once per process (feature flags + runtime
/// detection + `FI_SIMD` kill-switch), then cache it.
pub fn backend() -> Backend {
    use std::sync::atomic::{AtomicU8, Ordering};
    static CACHED: AtomicU8 = AtomicU8::new(0);
    match CACHED.load(Ordering::Relaxed) {
        1 => return Backend::Scalar,
        2 => return Backend::Avx2,
        3 => return Backend::Neon,
        _ => {}
    }
    let b = detect();
    CACHED.store(
        match b {
            Backend::Scalar => 1,
            Backend::Avx2 => 2,
            Backend::Neon => 3,
        },
        Ordering::Relaxed,
    );
    b
}

/// Backend name for bench `meta` stamping and calibration attribution.
pub fn backend_name() -> &'static str {
    match backend() {
        Backend::Scalar => "scalar",
        Backend::Avx2 => "avx2",
        Backend::Neon => "neon",
    }
}

fn detect() -> Backend {
    if matches!(std::env::var("FI_SIMD").as_deref(), Ok("0") | Ok("off")) {
        return Backend::Scalar;
    }
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") {
            return Backend::Avx2;
        }
    }
    #[cfg(all(feature = "simd", target_arch = "aarch64"))]
    {
        // NEON is baseline on aarch64 — no runtime probe needed.
        return Backend::Neon;
    }
    Backend::Scalar
}

/// Lane-block width of the fused rfft tile kernel, adapted to the
/// detected cache hierarchy. Resolved once per process and cached, like
/// [`backend`].
///
/// The fused kernel's per-block working set is ~`2·U·bd` packed f32s
/// plus four temp rows (`tiling::flops::tile_rfft_fused_scratch_bytes`);
/// the block width only changes *which* lanes share a pass, never the
/// per-lane expression shape, so any width preserves the module's
/// bit-exactness contract. Sizing: half the L1d budget for the packed
/// planes at the largest common tile (U = 256) gives
/// `bd = l1d_bytes / 2048`, rounded down to a multiple of 8 (whole AVX2
/// vectors, pairs of NEON vectors) and clamped to [8, 64]. Boxes whose
/// cache topology is unreadable (non-Linux, restricted /sys) keep the
/// measured default [`crate::fft::FUSED_BLOCK_D`] = 16. The
/// `FI_FUSED_BLOCK_D` env var overrides the probe for experiments and
/// bench reproducibility.
pub fn fused_block_d() -> usize {
    use std::sync::atomic::{AtomicUsize, Ordering};
    static CACHED: AtomicUsize = AtomicUsize::new(0);
    let got = CACHED.load(Ordering::Relaxed);
    if got != 0 {
        return got;
    }
    let bd = resolve_fused_block_d();
    CACHED.store(bd, Ordering::Relaxed);
    bd
}

fn resolve_fused_block_d() -> usize {
    if let Ok(v) = std::env::var("FI_FUSED_BLOCK_D") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    match l1d_cache_bytes() {
        Some(l1d) => ((l1d / 2048) & !7).clamp(8, 64),
        None => super::conv::FUSED_BLOCK_D,
    }
}

/// Probe the L1 data cache size from the Linux sysfs cache topology
/// (`/sys/devices/system/cpu/cpu0/cache/index*/`). Returns `None` when
/// the hierarchy is unreadable — callers fall back to the measured
/// default rather than guessing.
fn l1d_cache_bytes() -> Option<usize> {
    let base = std::path::Path::new("/sys/devices/system/cpu/cpu0/cache");
    for idx in 0..8 {
        let dir = base.join(format!("index{idx}"));
        let read = |f: &str| std::fs::read_to_string(dir.join(f)).ok();
        let (Some(level), Some(ty)) = (read("level"), read("type")) else {
            continue; // missing index dir: keep scanning the rest
        };
        if level.trim() != "1" || !ty.trim().eq_ignore_ascii_case("data") {
            continue;
        }
        let size = read("size")?;
        let size = size.trim();
        let (num, mult) = match size.strip_suffix(['K', 'k']) {
            Some(n) => (n, 1024),
            None => match size.strip_suffix(['M', 'm']) {
                Some(n) => (n, 1024 * 1024),
                None => (size, 1),
            },
        };
        return num.parse::<usize>().ok().map(|n| n * mult);
    }
    None
}

/// Scalar reference implementations. Public so the equivalence tests
/// (and any caller that must sidestep dispatch) can compare the
/// dispatched primitives against these bit-for-bit.
pub mod scalar {
    /// `(a_re, a_im) *= (b_re, b_im)` lane-wise.
    pub fn cmul_rows(are: &mut [f32], aim: &mut [f32], bre: &[f32], bim: &[f32]) {
        for k in 0..are.len() {
            let ar = are[k];
            let ai = aim[k];
            are[k] = ar * bre[k] - ai * bim[k];
            aim[k] = ar * bim[k] + ai * bre[k];
        }
    }

    /// Radix-2 butterfly with twiddle `w` over paired rows:
    /// `t = w·b; b = a - t; a = a + t`.
    pub fn butterfly_rows(
        re_a: &mut [f32],
        im_a: &mut [f32],
        re_b: &mut [f32],
        im_b: &mut [f32],
        wre: f32,
        wim: f32,
    ) {
        for k in 0..re_a.len() {
            let tre = wre * re_b[k] - wim * im_b[k];
            let tim = wre * im_b[k] + wim * re_b[k];
            re_b[k] = re_a[k] - tre;
            im_b[k] = im_a[k] - tim;
            re_a[k] += tre;
            im_a[k] += tim;
        }
    }

    /// Twiddle-free butterfly (`w == 1`): saves 4 mults/lane.
    pub fn butterfly_rows_w1(
        re_a: &mut [f32],
        im_a: &mut [f32],
        re_b: &mut [f32],
        im_b: &mut [f32],
    ) {
        for k in 0..re_a.len() {
            let tre = re_b[k];
            let tim = im_b[k];
            re_b[k] = re_a[k] - tre;
            im_b[k] = im_a[k] - tim;
            re_a[k] += tre;
            im_a[k] += tim;
        }
    }

    /// Forward half-spectrum unpack for bin `k` of the packed real
    /// transform: split `Z[k]`, `Z[j=m-k]` into even/odd parts and
    /// twiddle with `w^k = (wr, wi)`:
    /// `X[k] = He + w·Ho` (see `rfft::rfft_into`).
    #[allow(clippy::too_many_arguments)]
    pub fn rfft_unpack_row(
        xre: &mut [f32],
        xim: &mut [f32],
        zk_re: &[f32],
        zk_im: &[f32],
        zj_re: &[f32],
        zj_im: &[f32],
        wr: f32,
        wi: f32,
    ) {
        for t in 0..xre.len() {
            let ar = zk_re[t];
            let ai = zk_im[t];
            let br = zj_re[t];
            let bi = zj_im[t];
            let her = 0.5 * (ar + br);
            let hei = 0.5 * (ai - bi);
            let hor = 0.5 * (ai + bi);
            let hoi = 0.5 * (br - ar);
            xre[t] = her + wr * hor - wi * hoi;
            xim[t] = hei + wr * hoi + wi * hor;
        }
    }

    /// Inverse repack for bin `k`: fold `X[k]`, `X[j=m-k]` back into the
    /// packed complex spectrum `Z'[k]` with twiddle `w^k = (wr, wi)`
    /// (see `rfft::irfft_packed_unscaled`).
    #[allow(clippy::too_many_arguments)]
    pub fn irfft_repack_row(
        zre: &mut [f32],
        zim: &mut [f32],
        xk_re: &[f32],
        xk_im: &[f32],
        xj_re: &[f32],
        xj_im: &[f32],
        wr: f32,
        wi: f32,
    ) {
        for t in 0..zre.len() {
            let ar = xk_re[t];
            let ai = xk_im[t];
            let br = xj_re[t];
            let bi = xj_im[t];
            let s_re = ar + br;
            let s_im = ai - bi;
            let dd_re = ar - br;
            let dd_im = ai + bi;
            let t_re = wr * dd_re + wi * dd_im;
            let t_im = wr * dd_im - wi * dd_re;
            zre[t] = s_re - t_im;
            zim[t] = s_im + t_re;
        }
    }

    /// `dst += src · s` lane-wise (the 1/n-folded accumulate).
    pub fn acc_scaled(dst: &mut [f32], src: &[f32], s: f32) {
        for t in 0..dst.len() {
            dst[t] += src[t] * s;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    //! AVX2 row primitives: 8 f32 lanes per op, scalar tail. NO FMA —
    //! `_mm256_fmadd_ps` would change rounding vs the scalar loop and
    //! break the bit-exactness contract, so every expression is built
    //! from mul/add/sub in the scalar evaluation order.
    use super::scalar;
    use std::arch::x86_64::*;

    const W: usize = 8;

    #[target_feature(enable = "avx2")]
    pub unsafe fn cmul_rows(are: &mut [f32], aim: &mut [f32], bre: &[f32], bim: &[f32]) {
        let n = are.len();
        let mut k = 0;
        while k + W <= n {
            let ar = _mm256_loadu_ps(are.as_ptr().add(k));
            let ai = _mm256_loadu_ps(aim.as_ptr().add(k));
            let br = _mm256_loadu_ps(bre.as_ptr().add(k));
            let bi = _mm256_loadu_ps(bim.as_ptr().add(k));
            let re = _mm256_sub_ps(_mm256_mul_ps(ar, br), _mm256_mul_ps(ai, bi));
            let im = _mm256_add_ps(_mm256_mul_ps(ar, bi), _mm256_mul_ps(ai, br));
            _mm256_storeu_ps(are.as_mut_ptr().add(k), re);
            _mm256_storeu_ps(aim.as_mut_ptr().add(k), im);
            k += W;
        }
        scalar::cmul_rows(&mut are[k..], &mut aim[k..], &bre[k..], &bim[k..]);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn butterfly_rows(
        re_a: &mut [f32],
        im_a: &mut [f32],
        re_b: &mut [f32],
        im_b: &mut [f32],
        wre: f32,
        wim: f32,
    ) {
        let n = re_a.len();
        let wr = _mm256_set1_ps(wre);
        let wi = _mm256_set1_ps(wim);
        let mut k = 0;
        while k + W <= n {
            let br = _mm256_loadu_ps(re_b.as_ptr().add(k));
            let bi = _mm256_loadu_ps(im_b.as_ptr().add(k));
            let ar = _mm256_loadu_ps(re_a.as_ptr().add(k));
            let ai = _mm256_loadu_ps(im_a.as_ptr().add(k));
            let tre = _mm256_sub_ps(_mm256_mul_ps(wr, br), _mm256_mul_ps(wi, bi));
            let tim = _mm256_add_ps(_mm256_mul_ps(wr, bi), _mm256_mul_ps(wi, br));
            _mm256_storeu_ps(re_b.as_mut_ptr().add(k), _mm256_sub_ps(ar, tre));
            _mm256_storeu_ps(im_b.as_mut_ptr().add(k), _mm256_sub_ps(ai, tim));
            _mm256_storeu_ps(re_a.as_mut_ptr().add(k), _mm256_add_ps(ar, tre));
            _mm256_storeu_ps(im_a.as_mut_ptr().add(k), _mm256_add_ps(ai, tim));
            k += W;
        }
        let (ra, ia) = (&mut re_a[k..], &mut im_a[k..]);
        scalar::butterfly_rows(ra, ia, &mut re_b[k..], &mut im_b[k..], wre, wim);
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn butterfly_rows_w1(
        re_a: &mut [f32],
        im_a: &mut [f32],
        re_b: &mut [f32],
        im_b: &mut [f32],
    ) {
        let n = re_a.len();
        let mut k = 0;
        while k + W <= n {
            let br = _mm256_loadu_ps(re_b.as_ptr().add(k));
            let bi = _mm256_loadu_ps(im_b.as_ptr().add(k));
            let ar = _mm256_loadu_ps(re_a.as_ptr().add(k));
            let ai = _mm256_loadu_ps(im_a.as_ptr().add(k));
            _mm256_storeu_ps(re_b.as_mut_ptr().add(k), _mm256_sub_ps(ar, br));
            _mm256_storeu_ps(im_b.as_mut_ptr().add(k), _mm256_sub_ps(ai, bi));
            _mm256_storeu_ps(re_a.as_mut_ptr().add(k), _mm256_add_ps(ar, br));
            _mm256_storeu_ps(im_a.as_mut_ptr().add(k), _mm256_add_ps(ai, bi));
            k += W;
        }
        scalar::butterfly_rows_w1(&mut re_a[k..], &mut im_a[k..], &mut re_b[k..], &mut im_b[k..]);
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn rfft_unpack_row(
        xre: &mut [f32],
        xim: &mut [f32],
        zk_re: &[f32],
        zk_im: &[f32],
        zj_re: &[f32],
        zj_im: &[f32],
        wr: f32,
        wi: f32,
    ) {
        let n = xre.len();
        let half = _mm256_set1_ps(0.5);
        let vwr = _mm256_set1_ps(wr);
        let vwi = _mm256_set1_ps(wi);
        let mut t = 0;
        while t + W <= n {
            let ar = _mm256_loadu_ps(zk_re.as_ptr().add(t));
            let ai = _mm256_loadu_ps(zk_im.as_ptr().add(t));
            let br = _mm256_loadu_ps(zj_re.as_ptr().add(t));
            let bi = _mm256_loadu_ps(zj_im.as_ptr().add(t));
            let her = _mm256_mul_ps(half, _mm256_add_ps(ar, br));
            let hei = _mm256_mul_ps(half, _mm256_sub_ps(ai, bi));
            let hor = _mm256_mul_ps(half, _mm256_add_ps(ai, bi));
            let hoi = _mm256_mul_ps(half, _mm256_sub_ps(br, ar));
            // (her + wr·hor) - wi·hoi — same association as scalar
            let re = _mm256_sub_ps(
                _mm256_add_ps(her, _mm256_mul_ps(vwr, hor)),
                _mm256_mul_ps(vwi, hoi),
            );
            let im = _mm256_add_ps(
                _mm256_add_ps(hei, _mm256_mul_ps(vwr, hoi)),
                _mm256_mul_ps(vwi, hor),
            );
            _mm256_storeu_ps(xre.as_mut_ptr().add(t), re);
            _mm256_storeu_ps(xim.as_mut_ptr().add(t), im);
            t += W;
        }
        scalar::rfft_unpack_row(
            &mut xre[t..],
            &mut xim[t..],
            &zk_re[t..],
            &zk_im[t..],
            &zj_re[t..],
            &zj_im[t..],
            wr,
            wi,
        );
    }

    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn irfft_repack_row(
        zre: &mut [f32],
        zim: &mut [f32],
        xk_re: &[f32],
        xk_im: &[f32],
        xj_re: &[f32],
        xj_im: &[f32],
        wr: f32,
        wi: f32,
    ) {
        let n = zre.len();
        let vwr = _mm256_set1_ps(wr);
        let vwi = _mm256_set1_ps(wi);
        let mut t = 0;
        while t + W <= n {
            let ar = _mm256_loadu_ps(xk_re.as_ptr().add(t));
            let ai = _mm256_loadu_ps(xk_im.as_ptr().add(t));
            let br = _mm256_loadu_ps(xj_re.as_ptr().add(t));
            let bi = _mm256_loadu_ps(xj_im.as_ptr().add(t));
            let s_re = _mm256_add_ps(ar, br);
            let s_im = _mm256_sub_ps(ai, bi);
            let dd_re = _mm256_sub_ps(ar, br);
            let dd_im = _mm256_add_ps(ai, bi);
            let t_re = _mm256_add_ps(_mm256_mul_ps(vwr, dd_re), _mm256_mul_ps(vwi, dd_im));
            let t_im = _mm256_sub_ps(_mm256_mul_ps(vwr, dd_im), _mm256_mul_ps(vwi, dd_re));
            _mm256_storeu_ps(zre.as_mut_ptr().add(t), _mm256_sub_ps(s_re, t_im));
            _mm256_storeu_ps(zim.as_mut_ptr().add(t), _mm256_add_ps(s_im, t_re));
            t += W;
        }
        scalar::irfft_repack_row(
            &mut zre[t..],
            &mut zim[t..],
            &xk_re[t..],
            &xk_im[t..],
            &xj_re[t..],
            &xj_im[t..],
            wr,
            wi,
        );
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn acc_scaled(dst: &mut [f32], src: &[f32], s: f32) {
        let n = dst.len();
        let vs = _mm256_set1_ps(s);
        let mut t = 0;
        while t + W <= n {
            let d = _mm256_loadu_ps(dst.as_ptr().add(t));
            let v = _mm256_loadu_ps(src.as_ptr().add(t));
            _mm256_storeu_ps(dst.as_mut_ptr().add(t), _mm256_add_ps(d, _mm256_mul_ps(v, vs)));
            t += W;
        }
        scalar::acc_scaled(&mut dst[t..], &src[t..], s);
    }
}

#[cfg(all(feature = "simd", target_arch = "aarch64"))]
mod neon {
    //! NEON row primitives: 4 f32 lanes per op, scalar tail. Like the
    //! AVX2 path, strictly mul/add/sub (no `vfmaq_f32`) so every lane is
    //! bit-identical to the scalar loop.
    use super::scalar;
    use std::arch::aarch64::*;

    const W: usize = 4;

    #[target_feature(enable = "neon")]
    pub unsafe fn cmul_rows(are: &mut [f32], aim: &mut [f32], bre: &[f32], bim: &[f32]) {
        let n = are.len();
        let mut k = 0;
        while k + W <= n {
            let ar = vld1q_f32(are.as_ptr().add(k));
            let ai = vld1q_f32(aim.as_ptr().add(k));
            let br = vld1q_f32(bre.as_ptr().add(k));
            let bi = vld1q_f32(bim.as_ptr().add(k));
            let re = vsubq_f32(vmulq_f32(ar, br), vmulq_f32(ai, bi));
            let im = vaddq_f32(vmulq_f32(ar, bi), vmulq_f32(ai, br));
            vst1q_f32(are.as_mut_ptr().add(k), re);
            vst1q_f32(aim.as_mut_ptr().add(k), im);
            k += W;
        }
        scalar::cmul_rows(&mut are[k..], &mut aim[k..], &bre[k..], &bim[k..]);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn butterfly_rows(
        re_a: &mut [f32],
        im_a: &mut [f32],
        re_b: &mut [f32],
        im_b: &mut [f32],
        wre: f32,
        wim: f32,
    ) {
        let n = re_a.len();
        let wr = vdupq_n_f32(wre);
        let wi = vdupq_n_f32(wim);
        let mut k = 0;
        while k + W <= n {
            let br = vld1q_f32(re_b.as_ptr().add(k));
            let bi = vld1q_f32(im_b.as_ptr().add(k));
            let ar = vld1q_f32(re_a.as_ptr().add(k));
            let ai = vld1q_f32(im_a.as_ptr().add(k));
            let tre = vsubq_f32(vmulq_f32(wr, br), vmulq_f32(wi, bi));
            let tim = vaddq_f32(vmulq_f32(wr, bi), vmulq_f32(wi, br));
            vst1q_f32(re_b.as_mut_ptr().add(k), vsubq_f32(ar, tre));
            vst1q_f32(im_b.as_mut_ptr().add(k), vsubq_f32(ai, tim));
            vst1q_f32(re_a.as_mut_ptr().add(k), vaddq_f32(ar, tre));
            vst1q_f32(im_a.as_mut_ptr().add(k), vaddq_f32(ai, tim));
            k += W;
        }
        let (ra, ia) = (&mut re_a[k..], &mut im_a[k..]);
        scalar::butterfly_rows(ra, ia, &mut re_b[k..], &mut im_b[k..], wre, wim);
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn butterfly_rows_w1(
        re_a: &mut [f32],
        im_a: &mut [f32],
        re_b: &mut [f32],
        im_b: &mut [f32],
    ) {
        let n = re_a.len();
        let mut k = 0;
        while k + W <= n {
            let br = vld1q_f32(re_b.as_ptr().add(k));
            let bi = vld1q_f32(im_b.as_ptr().add(k));
            let ar = vld1q_f32(re_a.as_ptr().add(k));
            let ai = vld1q_f32(im_a.as_ptr().add(k));
            vst1q_f32(re_b.as_mut_ptr().add(k), vsubq_f32(ar, br));
            vst1q_f32(im_b.as_mut_ptr().add(k), vsubq_f32(ai, bi));
            vst1q_f32(re_a.as_mut_ptr().add(k), vaddq_f32(ar, br));
            vst1q_f32(im_a.as_mut_ptr().add(k), vaddq_f32(ai, bi));
            k += W;
        }
        scalar::butterfly_rows_w1(&mut re_a[k..], &mut im_a[k..], &mut re_b[k..], &mut im_b[k..]);
    }

    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn rfft_unpack_row(
        xre: &mut [f32],
        xim: &mut [f32],
        zk_re: &[f32],
        zk_im: &[f32],
        zj_re: &[f32],
        zj_im: &[f32],
        wr: f32,
        wi: f32,
    ) {
        let n = xre.len();
        let half = vdupq_n_f32(0.5);
        let vwr = vdupq_n_f32(wr);
        let vwi = vdupq_n_f32(wi);
        let mut t = 0;
        while t + W <= n {
            let ar = vld1q_f32(zk_re.as_ptr().add(t));
            let ai = vld1q_f32(zk_im.as_ptr().add(t));
            let br = vld1q_f32(zj_re.as_ptr().add(t));
            let bi = vld1q_f32(zj_im.as_ptr().add(t));
            let her = vmulq_f32(half, vaddq_f32(ar, br));
            let hei = vmulq_f32(half, vsubq_f32(ai, bi));
            let hor = vmulq_f32(half, vaddq_f32(ai, bi));
            let hoi = vmulq_f32(half, vsubq_f32(br, ar));
            let re = vsubq_f32(vaddq_f32(her, vmulq_f32(vwr, hor)), vmulq_f32(vwi, hoi));
            let im = vaddq_f32(vaddq_f32(hei, vmulq_f32(vwr, hoi)), vmulq_f32(vwi, hor));
            vst1q_f32(xre.as_mut_ptr().add(t), re);
            vst1q_f32(xim.as_mut_ptr().add(t), im);
            t += W;
        }
        scalar::rfft_unpack_row(
            &mut xre[t..],
            &mut xim[t..],
            &zk_re[t..],
            &zk_im[t..],
            &zj_re[t..],
            &zj_im[t..],
            wr,
            wi,
        );
    }

    #[target_feature(enable = "neon")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn irfft_repack_row(
        zre: &mut [f32],
        zim: &mut [f32],
        xk_re: &[f32],
        xk_im: &[f32],
        xj_re: &[f32],
        xj_im: &[f32],
        wr: f32,
        wi: f32,
    ) {
        let n = zre.len();
        let vwr = vdupq_n_f32(wr);
        let vwi = vdupq_n_f32(wi);
        let mut t = 0;
        while t + W <= n {
            let ar = vld1q_f32(xk_re.as_ptr().add(t));
            let ai = vld1q_f32(xk_im.as_ptr().add(t));
            let br = vld1q_f32(xj_re.as_ptr().add(t));
            let bi = vld1q_f32(xj_im.as_ptr().add(t));
            let s_re = vaddq_f32(ar, br);
            let s_im = vsubq_f32(ai, bi);
            let dd_re = vsubq_f32(ar, br);
            let dd_im = vaddq_f32(ai, bi);
            let t_re = vaddq_f32(vmulq_f32(vwr, dd_re), vmulq_f32(vwi, dd_im));
            let t_im = vsubq_f32(vmulq_f32(vwr, dd_im), vmulq_f32(vwi, dd_re));
            vst1q_f32(zre.as_mut_ptr().add(t), vsubq_f32(s_re, t_im));
            vst1q_f32(zim.as_mut_ptr().add(t), vaddq_f32(s_im, t_re));
            t += W;
        }
        scalar::irfft_repack_row(
            &mut zre[t..],
            &mut zim[t..],
            &xk_re[t..],
            &xk_im[t..],
            &xj_re[t..],
            &xj_im[t..],
            wr,
            wi,
        );
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn acc_scaled(dst: &mut [f32], src: &[f32], s: f32) {
        let n = dst.len();
        let vs = vdupq_n_f32(s);
        let mut t = 0;
        while t + W <= n {
            let d = vld1q_f32(dst.as_ptr().add(t));
            let v = vld1q_f32(src.as_ptr().add(t));
            vst1q_f32(dst.as_mut_ptr().add(t), vaddq_f32(d, vmulq_f32(v, vs)));
            t += W;
        }
        scalar::acc_scaled(&mut dst[t..], &src[t..], s);
    }
}

// ---------------------------------------------------------------------
// Dispatched entry points. Each checks the cached backend and forwards;
// the `unsafe` blocks are sound because the Avx2 arm is only reachable
// after `is_x86_feature_detected!("avx2")` returned true (and Neon only
// on aarch64 where NEON is architectural baseline).
// ---------------------------------------------------------------------

/// `(a_re, a_im) *= (b_re, b_im)` lane-wise, dispatched.
#[inline]
pub fn cmul_rows(are: &mut [f32], aim: &mut [f32], bre: &[f32], bim: &[f32]) {
    debug_assert_eq!(are.len(), aim.len());
    debug_assert_eq!(are.len(), bre.len());
    debug_assert_eq!(are.len(), bim.len());
    match backend() {
        Backend::Scalar => scalar::cmul_rows(are, aim, bre, bim),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Avx2 => unsafe { avx2::cmul_rows(are, aim, bre, bim) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Backend::Neon => unsafe { neon::cmul_rows(are, aim, bre, bim) },
        #[allow(unreachable_patterns)]
        _ => scalar::cmul_rows(are, aim, bre, bim),
    }
}

/// Twiddled radix-2 butterfly over paired rows, dispatched.
#[inline]
pub fn butterfly_rows(
    re_a: &mut [f32],
    im_a: &mut [f32],
    re_b: &mut [f32],
    im_b: &mut [f32],
    wre: f32,
    wim: f32,
) {
    match backend() {
        Backend::Scalar => scalar::butterfly_rows(re_a, im_a, re_b, im_b, wre, wim),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Avx2 => unsafe { avx2::butterfly_rows(re_a, im_a, re_b, im_b, wre, wim) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Backend::Neon => unsafe { neon::butterfly_rows(re_a, im_a, re_b, im_b, wre, wim) },
        #[allow(unreachable_patterns)]
        _ => scalar::butterfly_rows(re_a, im_a, re_b, im_b, wre, wim),
    }
}

/// Twiddle-free butterfly (`w == 1`), dispatched.
#[inline]
pub fn butterfly_rows_w1(re_a: &mut [f32], im_a: &mut [f32], re_b: &mut [f32], im_b: &mut [f32]) {
    match backend() {
        Backend::Scalar => scalar::butterfly_rows_w1(re_a, im_a, re_b, im_b),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Avx2 => unsafe { avx2::butterfly_rows_w1(re_a, im_a, re_b, im_b) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Backend::Neon => unsafe { neon::butterfly_rows_w1(re_a, im_a, re_b, im_b) },
        #[allow(unreachable_patterns)]
        _ => scalar::butterfly_rows_w1(re_a, im_a, re_b, im_b),
    }
}

/// Forward half-spectrum unpack for one bin row, dispatched.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn rfft_unpack_row(
    xre: &mut [f32],
    xim: &mut [f32],
    zk_re: &[f32],
    zk_im: &[f32],
    zj_re: &[f32],
    zj_im: &[f32],
    wr: f32,
    wi: f32,
) {
    match backend() {
        Backend::Scalar => scalar::rfft_unpack_row(xre, xim, zk_re, zk_im, zj_re, zj_im, wr, wi),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Avx2 => unsafe {
            avx2::rfft_unpack_row(xre, xim, zk_re, zk_im, zj_re, zj_im, wr, wi)
        },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Backend::Neon => unsafe {
            neon::rfft_unpack_row(xre, xim, zk_re, zk_im, zj_re, zj_im, wr, wi)
        },
        #[allow(unreachable_patterns)]
        _ => scalar::rfft_unpack_row(xre, xim, zk_re, zk_im, zj_re, zj_im, wr, wi),
    }
}

/// Inverse half-spectrum repack for one bin row, dispatched.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn irfft_repack_row(
    zre: &mut [f32],
    zim: &mut [f32],
    xk_re: &[f32],
    xk_im: &[f32],
    xj_re: &[f32],
    xj_im: &[f32],
    wr: f32,
    wi: f32,
) {
    match backend() {
        Backend::Scalar => scalar::irfft_repack_row(zre, zim, xk_re, xk_im, xj_re, xj_im, wr, wi),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Avx2 => unsafe {
            avx2::irfft_repack_row(zre, zim, xk_re, xk_im, xj_re, xj_im, wr, wi)
        },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Backend::Neon => unsafe {
            neon::irfft_repack_row(zre, zim, xk_re, xk_im, xj_re, xj_im, wr, wi)
        },
        #[allow(unreachable_patterns)]
        _ => scalar::irfft_repack_row(zre, zim, xk_re, xk_im, xj_re, xj_im, wr, wi),
    }
}

/// `dst += src · s` lane-wise, dispatched.
#[inline]
pub fn acc_scaled(dst: &mut [f32], src: &[f32], s: f32) {
    debug_assert_eq!(dst.len(), src.len());
    match backend() {
        Backend::Scalar => scalar::acc_scaled(dst, src, s),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        Backend::Avx2 => unsafe { avx2::acc_scaled(dst, src, s) },
        #[cfg(all(feature = "simd", target_arch = "aarch64"))]
        Backend::Neon => unsafe { neon::acc_scaled(dst, src, s) },
        #[allow(unreachable_patterns)]
        _ => scalar::acc_scaled(dst, src, s),
    }
}

/// Endpoint bins of the packed real transform: `X[0] = (a+b, 0)`,
/// `X[m] = (a-b, 0)` from `Z[0] = (a, b)`. Pure add/sub — the compiler
/// auto-vectorizes this trivially, so it has no hand-rolled vector arm.
pub fn rfft_endpoints_row(
    x0_re: &mut [f32],
    x0_im: &mut [f32],
    xm_re: &mut [f32],
    xm_im: &mut [f32],
    z0_re: &[f32],
    z0_im: &[f32],
) {
    for t in 0..x0_re.len() {
        let a = z0_re[t];
        let b = z0_im[t];
        x0_re[t] = a + b;
        x0_im[t] = 0.0;
        xm_re[t] = a - b;
        xm_im[t] = 0.0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_row(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| rng.normal_f32()).collect()
    }

    #[test]
    fn fused_block_d_is_cached_and_sane() {
        let bd = fused_block_d();
        assert!(bd > 0, "block width must be positive");
        assert_eq!(bd, fused_block_d(), "one-shot resolution must be stable");
        // Unless FI_FUSED_BLOCK_D forces something else, the probe result
        // is either the cache-derived width (multiple of 8 in [8, 64]) or
        // the measured fallback constant.
        if std::env::var("FI_FUSED_BLOCK_D").is_err() {
            assert!(
                (bd % 8 == 0 && (8..=64).contains(&bd)) || bd == super::super::conv::FUSED_BLOCK_D,
                "unexpected probed width {bd}"
            );
        }
    }

    /// Every dispatched primitive must be bit-identical to the scalar
    /// reference — including tail lanes shorter than the vector width
    /// (d = 1, 3, 7) and widths straddling one/two vectors (9, 15, 17).
    #[test]
    fn dispatched_matches_scalar_bitexact() {
        for d in [1usize, 3, 7, 8, 9, 15, 16, 17, 64] {
            for seed in 0..3u64 {
                let bre = rand_row(d, 100 + seed);
                let bim = rand_row(d, 200 + seed);
                let (wr, wi) = (0.731f32, -0.682f32);

                // cmul
                let mut re_s = rand_row(d, seed);
                let mut im_s = rand_row(d, 10 + seed);
                let mut re_v = re_s.clone();
                let mut im_v = im_s.clone();
                scalar::cmul_rows(&mut re_s, &mut im_s, &bre, &bim);
                cmul_rows(&mut re_v, &mut im_v, &bre, &bim);
                assert_eq!(re_s, re_v, "cmul re d={d}");
                assert_eq!(im_s, im_v, "cmul im d={d}");

                // butterfly (twiddled + w1)
                let mut ra_s = rand_row(d, 20 + seed);
                let mut ia_s = rand_row(d, 30 + seed);
                let mut rb_s = rand_row(d, 40 + seed);
                let mut ib_s = rand_row(d, 50 + seed);
                let (mut ra_v, mut ia_v, mut rb_v, mut ib_v) =
                    (ra_s.clone(), ia_s.clone(), rb_s.clone(), ib_s.clone());
                scalar::butterfly_rows(&mut ra_s, &mut ia_s, &mut rb_s, &mut ib_s, wr, wi);
                butterfly_rows(&mut ra_v, &mut ia_v, &mut rb_v, &mut ib_v, wr, wi);
                assert_eq!((ra_s, ia_s, rb_s, ib_s), (ra_v, ia_v, rb_v, ib_v), "bfly d={d}");

                let mut ra_s = rand_row(d, 21 + seed);
                let mut ia_s = rand_row(d, 31 + seed);
                let mut rb_s = rand_row(d, 41 + seed);
                let mut ib_s = rand_row(d, 51 + seed);
                let (mut ra_v, mut ia_v, mut rb_v, mut ib_v) =
                    (ra_s.clone(), ia_s.clone(), rb_s.clone(), ib_s.clone());
                scalar::butterfly_rows_w1(&mut ra_s, &mut ia_s, &mut rb_s, &mut ib_s);
                butterfly_rows_w1(&mut ra_v, &mut ia_v, &mut rb_v, &mut ib_v);
                assert_eq!((ra_s, ia_s, rb_s, ib_s), (ra_v, ia_v, rb_v, ib_v), "bfly_w1 d={d}");

                // rfft unpack / irfft repack
                let zk_re = rand_row(d, 60 + seed);
                let zk_im = rand_row(d, 70 + seed);
                let zj_re = rand_row(d, 80 + seed);
                let zj_im = rand_row(d, 90 + seed);
                let mut xr_s = vec![0.0; d];
                let mut xi_s = vec![0.0; d];
                let mut xr_v = vec![0.0; d];
                let mut xi_v = vec![0.0; d];
                let (xr, xi) = (&mut xr_s, &mut xi_s);
                scalar::rfft_unpack_row(xr, xi, &zk_re, &zk_im, &zj_re, &zj_im, wr, wi);
                rfft_unpack_row(&mut xr_v, &mut xi_v, &zk_re, &zk_im, &zj_re, &zj_im, wr, wi);
                assert_eq!((xr_s, xi_s), (xr_v, xi_v), "unpack d={d}");

                let mut zr_s = vec![0.0; d];
                let mut zi_s = vec![0.0; d];
                let mut zr_v = vec![0.0; d];
                let mut zi_v = vec![0.0; d];
                let (zr, zi) = (&mut zr_s, &mut zi_s);
                scalar::irfft_repack_row(zr, zi, &zk_re, &zk_im, &zj_re, &zj_im, wr, wi);
                irfft_repack_row(&mut zr_v, &mut zi_v, &zk_re, &zk_im, &zj_re, &zj_im, wr, wi);
                assert_eq!((zr_s, zi_s), (zr_v, zi_v), "repack d={d}");

                // scaled accumulate
                let mut a_s = rand_row(d, 110 + seed);
                let mut a_v = a_s.clone();
                let src = rand_row(d, 120 + seed);
                scalar::acc_scaled(&mut a_s, &src, 0.125);
                acc_scaled(&mut a_v, &src, 0.125);
                assert_eq!(a_s, a_v, "acc d={d}");
            }
        }
    }

    #[test]
    fn backend_is_cached_and_named() {
        let b = backend();
        assert_eq!(backend(), b, "dispatch decision must be stable");
        let name = backend_name();
        assert!(["scalar", "avx2", "neon"].contains(&name));
        // without the cargo feature, the backend is always scalar
        if !cfg!(feature = "simd") {
            assert_eq!(b, Backend::Scalar);
        }
    }

    #[test]
    fn endpoints_row_matches_definition() {
        let z0_re = [1.5f32, -2.0, 0.25];
        let z0_im = [0.5f32, 1.0, -4.0];
        let mut x0_re = [0.0f32; 3];
        let mut x0_im = [9.0f32; 3];
        let mut xm_re = [0.0f32; 3];
        let mut xm_im = [9.0f32; 3];
        rfft_endpoints_row(&mut x0_re, &mut x0_im, &mut xm_re, &mut xm_im, &z0_re, &z0_im);
        for t in 0..3 {
            assert_eq!(x0_re[t], z0_re[t] + z0_im[t]);
            assert_eq!(xm_re[t], z0_re[t] - z0_im[t]);
            assert_eq!(x0_im[t], 0.0);
            assert_eq!(xm_im[t], 0.0);
        }
    }
}
