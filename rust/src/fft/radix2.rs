//! Scalar radix-2 FFT (tests, filter-spectrum precompute — not the hot
//! path; the token loop uses `vecfft`, which batches over the D axis).

use super::complex::Cpx;
use super::plan::Plan;

/// In-place forward DFT: X[k] = sum_j x[j] e^{-2 pi i jk / n}.
pub fn forward(plan: &Plan, data: &mut [Cpx]) {
    assert_eq!(data.len(), plan.n);
    let n = plan.n;
    if n == 1 {
        return;
    }
    // bit-reverse permutation
    for i in 0..n {
        let j = plan.bitrev[i] as usize;
        if i < j {
            data.swap(i, j);
        }
    }
    let mut len = 1;
    while len < n {
        let step = n / (2 * len);
        for base in (0..n).step_by(2 * len) {
            for j in 0..len {
                let w = Cpx::new(plan.tw_re[j * step], plan.tw_im[j * step]);
                let a = data[base + j];
                let t = w * data[base + j + len];
                data[base + j] = a + t;
                data[base + j + len] = a - t;
            }
        }
        len *= 2;
    }
}

/// In-place inverse DFT *without* the 1/n scale (caller folds it in).
pub fn inverse_unscaled(plan: &Plan, data: &mut [Cpx]) {
    // conj -> forward -> conj equals the inverse transform (x n).
    for c in data.iter_mut() {
        *c = c.conj();
    }
    forward(plan, data);
    for c in data.iter_mut() {
        *c = c.conj();
    }
}

/// Full inverse DFT with scaling.
pub fn inverse(plan: &Plan, data: &mut [Cpx]) {
    inverse_unscaled(plan, data);
    let s = 1.0 / plan.n as f32;
    for c in data.iter_mut() {
        *c = c.scale(s);
    }
}

/// O(n^2) reference DFT for tests.
pub fn dft_naive(x: &[Cpx]) -> Vec<Cpx> {
    let n = x.len();
    (0..n)
        .map(|k| {
            let mut acc = Cpx::ZERO;
            for (j, &v) in x.iter().enumerate() {
                let w = Cpx::cis(-2.0 * std::f64::consts::PI * (j * k) as f64 / n as f64);
                acc = acc + v * w;
            }
            acc
        })
        .collect()
}

/// Spectrum of a real sequence (zero-padded/truncated to plan.n).
pub fn spectrum_of_real(plan: &Plan, x: &[f32]) -> Vec<Cpx> {
    let mut buf = vec![Cpx::ZERO; plan.n];
    for (i, &v) in x.iter().take(plan.n).enumerate() {
        buf[i] = Cpx::real(v);
    }
    forward(plan, &mut buf);
    buf
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Prng;

    fn rand_cpx(n: usize, seed: u64) -> Vec<Cpx> {
        let mut rng = Prng::new(seed);
        (0..n).map(|_| Cpx::new(rng.normal_f32(), rng.normal_f32())).collect()
    }

    #[test]
    fn matches_naive_dft() {
        for n in [1usize, 2, 4, 8, 32, 128] {
            let plan = Plan::new(n);
            let x = rand_cpx(n, n as u64);
            let mut got = x.clone();
            forward(&plan, &mut got);
            let want = dft_naive(&x);
            for (g, w) in got.iter().zip(&want) {
                assert!((g.re - w.re).abs() < 1e-3 * (n as f32).sqrt(), "n={n}");
                assert!((g.im - w.im).abs() < 1e-3 * (n as f32).sqrt(), "n={n}");
            }
        }
    }

    #[test]
    fn roundtrip_identity() {
        for n in [2usize, 16, 256, 1024] {
            let plan = Plan::new(n);
            let x = rand_cpx(n, 7);
            let mut buf = x.clone();
            forward(&plan, &mut buf);
            inverse(&plan, &mut buf);
            for (a, b) in buf.iter().zip(&x) {
                assert!((a.re - b.re).abs() < 1e-4, "n={n}");
                assert!((a.im - b.im).abs() < 1e-4, "n={n}");
            }
        }
    }

    #[test]
    fn impulse_has_flat_spectrum() {
        let plan = Plan::new(8);
        let mut x = vec![Cpx::ZERO; 8];
        x[0] = Cpx::ONE;
        forward(&plan, &mut x);
        for c in x {
            assert!((c.re - 1.0).abs() < 1e-6 && c.im.abs() < 1e-6);
        }
    }

    #[test]
    fn dc_input_concentrates_at_bin0() {
        let plan = Plan::new(16);
        let mut x = vec![Cpx::ONE; 16];
        forward(&plan, &mut x);
        assert!((x[0].re - 16.0).abs() < 1e-4);
        for c in &x[1..] {
            assert!(c.abs() < 1e-4);
        }
    }

    #[test]
    fn spectrum_of_real_pads() {
        let plan = Plan::new(8);
        let s = spectrum_of_real(&plan, &[1.0, 2.0]);
        let want = dft_naive(&[
            Cpx::real(1.0),
            Cpx::real(2.0),
            Cpx::ZERO,
            Cpx::ZERO,
            Cpx::ZERO,
            Cpx::ZERO,
            Cpx::ZERO,
            Cpx::ZERO,
        ]);
        for (g, w) in s.iter().zip(&want) {
            assert!((g.re - w.re).abs() < 1e-5 && (g.im - w.im).abs() < 1e-5);
        }
    }
}
