//! Scalar complex arithmetic for the FFT substrate.

use std::ops::{Add, Mul, Neg, Sub};

/// A complex f32 (scalar path: tests, filter-spectrum precompute).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Cpx {
    pub re: f32,
    pub im: f32,
}

impl Cpx {
    pub const ZERO: Cpx = Cpx { re: 0.0, im: 0.0 };
    pub const ONE: Cpx = Cpx { re: 1.0, im: 0.0 };

    pub fn new(re: f32, im: f32) -> Cpx {
        Cpx { re, im }
    }

    pub fn real(re: f32) -> Cpx {
        Cpx { re, im: 0.0 }
    }

    /// e^{i theta}.
    pub fn cis(theta: f64) -> Cpx {
        Cpx { re: theta.cos() as f32, im: theta.sin() as f32 }
    }

    pub fn conj(self) -> Cpx {
        Cpx { re: self.re, im: -self.im }
    }

    pub fn scale(self, s: f32) -> Cpx {
        Cpx { re: self.re * s, im: self.im * s }
    }

    pub fn abs(self) -> f32 {
        (self.re * self.re + self.im * self.im).sqrt()
    }
}

impl Add for Cpx {
    type Output = Cpx;
    #[inline]
    fn add(self, o: Cpx) -> Cpx {
        Cpx { re: self.re + o.re, im: self.im + o.im }
    }
}

impl Sub for Cpx {
    type Output = Cpx;
    #[inline]
    fn sub(self, o: Cpx) -> Cpx {
        Cpx { re: self.re - o.re, im: self.im - o.im }
    }
}

impl Mul for Cpx {
    type Output = Cpx;
    #[inline]
    fn mul(self, o: Cpx) -> Cpx {
        Cpx {
            re: self.re * o.re - self.im * o.im,
            im: self.re * o.im + self.im * o.re,
        }
    }
}

impl Neg for Cpx {
    type Output = Cpx;
    fn neg(self) -> Cpx {
        Cpx { re: -self.re, im: -self.im }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let a = Cpx::new(1.0, 2.0);
        let b = Cpx::new(3.0, -1.0);
        assert_eq!(a + b, Cpx::new(4.0, 1.0));
        assert_eq!(a - b, Cpx::new(-2.0, 3.0));
        assert_eq!(a * b, Cpx::new(5.0, 5.0)); // (1+2i)(3-i) = 3 - i + 6i + 2 = 5+5i
        assert_eq!(a.conj(), Cpx::new(1.0, -2.0));
    }

    #[test]
    fn cis_unit_circle() {
        let w = Cpx::cis(std::f64::consts::FRAC_PI_2);
        assert!((w.re - 0.0).abs() < 1e-6);
        assert!((w.im - 1.0).abs() < 1e-6);
        assert!((Cpx::cis(0.3).abs() - 1.0).abs() < 1e-6);
    }
}
