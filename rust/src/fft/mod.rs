//! Native FFT substrate: plans, scalar + vectorized radix-2 transforms,
//! the real-input (rfft) half-spectrum pipeline, and the Lemma-1 tile
//! convolution used by the `rust_fft` tau implementation (the FlashFFTConv
//! analogue on this testbed).

pub mod complex;
pub mod conv;
pub mod plan;
pub mod radix2;
pub mod rfft;
pub mod simd;
pub mod vecfft;

pub use complex::Cpx;
pub use conv::{
    spectrum_planes, tile_conv_direct_into, tile_conv_fft_into, tile_conv_rfft_fused_into,
    tile_conv_rfft_into, BlockedSpectrum, TileScratch, FUSED_BLOCK_D,
};
pub use plan::{Plan, PlanCache};
pub use rfft::{spectrum_halfplanes, RfftPlan, RfftPlanCache};
