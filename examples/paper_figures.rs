//! Compact, fast-running versions of the paper's headline comparisons —
//! a guided tour for a new user (the full-scale regenerators live in
//! `rust/benches/`, run them with `cargo bench`).
//!
//!     cargo run --release --example paper_figures

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::{calibrate, RhoCache, TauKind};
use flash_inference::util::benchkit::{fmt_ns, Table};

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts/synthetic".into());
    let rt = Runtime::load(&dir)?;
    let len = rt.dims.l.min(1024);
    println!(
        "mini paper tour on {dir} (M={} D={} L={len})\n",
        rt.dims.m, rt.dims.d
    );

    // --- Fig 2a/2b in miniature: method comparison -------------------------
    println!("[1/3] method comparison (Fig 2a/2b shape)");
    let mut table = Table::new(&["method", "total", "mixer", "mixer_share_%"]);
    let mut flash_mixer = 0.0;
    let mut lazy_mixer = 0.0;
    for (name, method, tau) in [
        ("lazy", Method::Lazy, TauKind::RustDirect),
        ("eager", Method::Eager, TauKind::RustDirect),
        ("flash", Method::Flash, TauKind::Hybrid),
    ] {
        let mut eng = Engine::new(&rt, EngineOpts { method, tau, ..Default::default() })?;
        eng.prewarm(len)?;
        let out = eng.generate(len)?;
        let t = &out.metrics.totals;
        if name == "flash" {
            flash_mixer = t.mixer_ns;
        }
        if name == "lazy" {
            lazy_mixer = t.mixer_ns;
        }
        table.row(vec![
            name.into(),
            fmt_ns(t.total_ns()),
            fmt_ns(t.mixer_ns),
            format!("{:.1}", 100.0 * t.mixer_ns / t.total_ns()),
        ]);
    }
    table.print();
    println!(
        "  -> mixer speedup lazy/flash at L={len}: {:.1}x (grows ~L/log²L with L)\n",
        lazy_mixer / flash_mixer.max(1.0)
    );

    // --- Fig 3a in miniature: the tau pareto frontier ----------------------
    println!("[2/3] tau pareto frontier (Fig 3a shape, U <= 64)");
    let cache = RhoCache::new(&rt)?;
    let (_, rows) = calibrate(&cache, 64, 1, 3)?;
    let mut t3 = Table::new(&["U", "rust_direct", "rust_fft", "pjrt_direct", "pjrt_fft", "winner"]);
    for r in &rows {
        let mut cells = vec![r.u.to_string()];
        for (_, ns) in &r.medians_ns {
            cells.push(fmt_ns(*ns));
        }
        cells.push(r.winner.as_str().into());
        t3.row(cells);
    }
    t3.print();

    // --- Fig 2c in miniature: latency spikes at large-tile positions -------
    println!("\n[3/3] per-token latency spikes (Fig 2c shape)");
    let mut eng = Engine::new(
        &rt,
        EngineOpts { method: Method::Flash, tau: TauKind::Hybrid, ..Default::default() },
    )?;
    eng.prewarm(len)?;
    eng.generate(len)?;
    let out = eng.generate(len)?;
    let lats = out.metrics.token_latencies_ns();
    let mut sorted = lats.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!(
        "  p50 {} vs max {} — the spikes sit exactly at positions divisible by\n  \
         large powers of two (tile sides), and 93.75% of tokens use U <= 8.",
        fmt_ns(sorted[len / 2]),
        fmt_ns(sorted[len - 1])
    );
    println!("\nfull-scale regenerators: cargo bench   (see rust/benches/, EXPERIMENTS.md)");
    Ok(())
}
