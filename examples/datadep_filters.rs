//! Data-dependent filters demo (Appendix B): the future-work direction the
//! paper highlights — causal, input-gated convolution filters — served by
//! Algorithm 5's parallelogram tiling with *exactly* the lazy semantics.
//!
//!     cargo run --release --example datadep_filters

use flash_inference::engine::datadep::{DataDepCfg, DataDepEngine};
use flash_inference::util::benchkit::fmt_ns;

fn main() {
    let len: usize = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(2048);
    let cfg = DataDepCfg { m: 4, d: 32, len, seed: 7 };
    println!(
        "data-dependent LCSM: M={} D={} L={len}; rho[l,t] = base[l,t] * sigmoid(y_l[t])",
        cfg.m, cfg.d
    );
    let eng = DataDepEngine::new(cfg);

    println!("\nrunning lazy O(L²) reference ...");
    let lazy = eng.generate_lazy(len);
    println!("  {} | {:.2e} mixer FLOPs", fmt_ns(lazy.wall.as_nanos() as f64),
             lazy.flops.mixer_flops as f64);

    println!("running Algorithm 5 (relaxed parallelogram tiling) ...");
    let alg5 = eng.generate_alg5(len);
    println!("  {} | {:.2e} mixer FLOPs | {} tile convs",
             fmt_ns(alg5.wall.as_nanos() as f64),
             alg5.flops.mixer_flops as f64,
             alg5.flops.tau_calls);

    let err = alg5.streams.rel_l2(&lazy.streams);
    println!("\nexactness: rel_l2(alg5, lazy) = {err:.2e}");
    println!(
        "speedup:   {:.2}x wall, {:.1}x FLOPs",
        lazy.wall.as_secs_f64() / alg5.wall.as_secs_f64(),
        lazy.flops.mixer_flops as f64 / alg5.flops.mixer_flops as f64
    );
    assert!(err < 1e-4, "exactness violated");
    println!("OK — data-dependent filters served exactly in O(L log² L).");
}
