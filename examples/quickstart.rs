//! Quickstart: load a model build, run Flash Inference, print the result.
//!
//!     make artifacts && cargo run --release --example quickstart
//!
//! Walks the full three-layer stack: the HLO artifacts (lowered once from
//! JAX/Pallas by `make artifacts`) are compiled on the PJRT CPU client and
//! driven by the rust tile scheduler — no python anywhere on this path.

use flash_inference::engine::{Engine, EngineOpts, Method};
use flash_inference::runtime::Runtime;
use flash_inference::tau::TauKind;
use flash_inference::util::benchkit::fmt_ns;

fn main() -> anyhow::Result<()> {
    let dir = std::env::args().nth(1).unwrap_or_else(|| "artifacts/synthetic".into());
    let len: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(512);

    // 1. load the artifact build (manifest + weights + PJRT client)
    let rt = Runtime::load(&dir)?;
    let d = rt.dims;
    println!(
        "loaded {dir}: {} | M={} mixers, D={} dims, L={} max positions, B={}",
        d.variant.as_str(), d.m, d.d, d.l, d.b
    );

    // 2. build the engine: Flash tiling with the calibrated Hybrid tau
    let mut engine = Engine::new(
        &rt,
        EngineOpts { method: Method::Flash, tau: TauKind::Hybrid, ..Default::default() },
    )?;
    engine.prewarm(len)?;

    // 3. generate autoregressively
    let out = engine.generate(len)?;
    let m = &out.metrics;
    println!(
        "generated {} positions in {} — {:.0} tok/s",
        out.steps,
        fmt_ns(m.wall.as_nanos() as f64),
        out.steps as f64 / m.wall.as_secs_f64()
    );
    println!(
        "breakdown: mixer {} ({:.1}%), blocks+head {} , sampling {}",
        fmt_ns(m.totals.mixer_ns),
        100.0 * m.totals.mixer_ns / m.totals.total_ns(),
        fmt_ns(m.totals.step_ns),
        fmt_ns(m.totals.sample_ns)
    );
    println!(
        "tau calls: {} across {} tile sizes (O(L log^2 L) schedule)",
        out.flops.tau_calls,
        out.flops.tau_call_hist.len()
    );
    if let Some(tokens) = &out.tokens {
        println!("first tokens: {:?}", &tokens[0][..tokens[0].len().min(12)]);
    }
    Ok(())
}
