//! End-to-end serving driver (the EXPERIMENTS.md validation run):
//! start the HTTP server in-process on the Hyena build, replay a Poisson
//! workload trace of batched requests over loopback, and report
//! latency/throughput — a small but real serving deployment of the system.
//!
//!     make artifacts && cargo run --release --example serve_and_query

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use flash_inference::config::ServerConfig;
use flash_inference::metrics::LatencyRecorder;
use flash_inference::server::Server;
use flash_inference::trace::{TraceConfig, WorkloadTrace};
use flash_inference::util::json::Json;

fn post_generate(addr: std::net::SocketAddr, max_tokens: usize) -> anyhow::Result<(usize, f64)> {
    let body = format!("{{\"max_tokens\": {max_tokens}}}");
    let raw = format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    );
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw.as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
    let payload = buf.split("\r\n\r\n").nth(1).unwrap_or("{}");
    let j = Json::parse(payload).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    let toks = j.get("tokens").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(max_tokens);
    Ok((toks, latency_ms))
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts/hyena".into());
    let cfg = ServerConfig {
        port: 0, // ephemeral
        artifacts: artifacts.clone().into(),
        ..Default::default()
    };
    println!("starting server on {artifacts} ...");
    let server = Server::start(cfg)?;
    println!("serving at http://{}", server.addr);

    // Poisson trace: 24 requests, ~2 rps, 16-128 tokens each
    let trace = WorkloadTrace::generate(TraceConfig {
        rate: 2.0,
        num_requests: 24,
        min_tokens: 16,
        max_tokens: 128,
        seed: 7,
    });
    println!(
        "replaying {} requests over ~{:.1}s ({} tokens total)",
        trace.requests.len(),
        trace.duration_s(),
        trace.total_tokens()
    );

    let t0 = Instant::now();
    let mut handles = Vec::new();
    let addr = server.addr;
    for req in trace.requests.clone() {
        handles.push(std::thread::spawn(move || {
            let wait = Duration::from_secs_f64(req.arrival_s);
            let since = t0.elapsed();
            if wait > since {
                std::thread::sleep(wait - since);
            }
            post_generate(addr, req.max_tokens)
        }));
    }

    let mut lat = LatencyRecorder::unbounded();
    let mut tokens = 0usize;
    let mut failures = 0usize;
    for h in handles {
        match h.join().unwrap() {
            Ok((toks, ms)) => {
                tokens += toks;
                lat.record_ns(ms * 1e6);
            }
            Err(e) => {
                eprintln!("request failed: {e:#}");
                failures += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== serving results ===");
    println!("requests: {} ok, {} failed", lat.count(), failures);
    println!("tokens:   {tokens} in {wall:.2}s  ->  {:.1} tok/s", tokens as f64 / wall);
    println!(
        "latency:  p50 {:.1}ms  p95 {:.1}ms  max {:.1}ms",
        lat.percentile_ns(50.0) / 1e6,
        lat.percentile_ns(95.0) / 1e6,
        lat.max_ns() / 1e6
    );

    // one streaming request: tokens leave the engine per position over
    // chunked NDJSON instead of arriving once the whole rollout is done
    println!("\n=== streaming request (\"stream\": true) ===");
    let body = "{\"max_tokens\": 32, \"stream\": true}";
    let mut s = TcpStream::connect(addr)?;
    s.write_all(
        format!(
            "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
            body.len(),
            body
        )
        .as_bytes(),
    )?;
    let t0 = Instant::now();
    let mut raw = String::new();
    s.read_to_string(&mut raw)?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    let payload = flash_inference::server::http::decode_chunked(
        raw.split("\r\n\r\n").nth(1).unwrap_or(""),
    );
    let events = payload.lines().filter(|l| l.contains("\"pos\"")).count();
    let done = payload.lines().rfind(|l| l.contains("\"done\"")).unwrap_or("");
    println!("received {events} incremental events in {ms:.1}ms; summary: {done}");

    // scrape the server's own metrics
    let mut s = TcpStream::connect(addr)?;
    s.write_all(b"GET /metrics HTTP/1.1\r\n\r\n")?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let metrics = buf.split("\r\n\r\n").nth(1).unwrap_or("");
    println!("\n=== server metrics ===");
    for line in metrics.lines().filter(|l| !l.starts_with('#')) {
        println!("  {line}");
    }
    server.stop();
    Ok(())
}
