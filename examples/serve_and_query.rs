//! End-to-end serving driver (the EXPERIMENTS.md validation run and the
//! CI `serving-smoke` gate): start the HTTP server in-process, replay a
//! Poisson workload trace of batched requests over loopback, demonstrate
//! per-position streaming, then run the **continuous-admission probe** —
//! a long streaming request holds the batch while a staggered short
//! request is seeded into a free lane mid-batch, and the short request's
//! rollout is checked for bit-identical checksums against a fresh rerun
//! of the same request. Any non-200, checksum mismatch, or failure to
//! observe a mid-batch admission exits nonzero (CI fails).
//!
//!     make artifacts && cargo run --release --example serve_and_query
//!     # or: cargo run --release --example serve_and_query artifacts/synthetic

use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use flash_inference::config::ServerConfig;
use flash_inference::engine::EngineOpts;
use flash_inference::metrics::LatencyRecorder;
use flash_inference::server::Server;
use flash_inference::tau::TauKind;
use flash_inference::trace::{TraceConfig, WorkloadTrace};
use flash_inference::util::benchkit;
use flash_inference::util::json::Json;

fn raw_post(body: &str) -> String {
    format!(
        "POST /v1/generate HTTP/1.1\r\nContent-Length: {}\r\n\r\n{}",
        body.len(),
        body
    )
}

fn post_generate(addr: std::net::SocketAddr, max_tokens: usize) -> anyhow::Result<(usize, f64)> {
    let body = format!("{{\"max_tokens\": {max_tokens}}}");
    let t0 = Instant::now();
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw_post(&body).as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    let latency_ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(buf.contains("200 OK"), "non-200: {}", &buf[..buf.len().min(200)]);
    let payload = buf.split("\r\n\r\n").nth(1).unwrap_or("{}");
    let j = Json::parse(payload).map_err(|e| anyhow::anyhow!("bad response: {e}"))?;
    let toks = j.get("tokens").and_then(Json::as_arr).map(|a| a.len()).unwrap_or(max_tokens);
    Ok((toks, latency_ms))
}

/// Buffered POST returning the parsed JSON document (status must be 200).
fn post_generate_json(addr: std::net::SocketAddr, body: &str) -> anyhow::Result<Json> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw_post(body).as_bytes())?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    anyhow::ensure!(buf.contains("200 OK"), "non-200: {}", &buf[..buf.len().min(300)]);
    let payload = buf.split("\r\n\r\n").nth(1).unwrap_or("{}");
    Json::parse(payload).map_err(|e| anyhow::anyhow!("bad response body: {e}"))
}

/// Read from the socket until `needle` appears (or the stream closes).
fn read_until(s: &mut TcpStream, needle: &[u8]) -> anyhow::Result<Vec<u8>> {
    let mut buf = Vec::new();
    let mut chunk = [0u8; 1024];
    loop {
        let n = s.read(&mut chunk)?;
        if n == 0 {
            anyhow::bail!(
                "stream closed before {:?} appeared",
                String::from_utf8_lossy(needle)
            );
        }
        buf.extend_from_slice(&chunk[..n]);
        if buf.windows(needle.len()).any(|w| w == needle) {
            return Ok(buf);
        }
    }
}

fn scrape_metrics(addr: std::net::SocketAddr) -> anyhow::Result<String> {
    let mut s = TcpStream::connect(addr)?;
    s.write_all(b"GET /metrics HTTP/1.1\r\n\r\n")?;
    let mut buf = String::new();
    s.read_to_string(&mut buf)?;
    Ok(buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string())
}

/// The continuous-admission probe: hold the batch open with a long
/// streaming request, land a short staggered request mid-batch, then
/// verify the short request's rollout is bit-identical to a fresh rerun.
fn admission_probe(addr: std::net::SocketAddr) -> anyhow::Result<()> {
    // per-request sampling: seed + sigma cover the synthetic variant,
    // temperature/top_k the LM variant — the unused knobs are ignored
    let probe_body =
        "{\"max_tokens\": 16, \"seed\": 9, \"sigma\": 0.05, \"temperature\": 0.8, \"top_k\": 8}";
    let mut probe: Option<Json> = None;
    for attempt in 1..=3 {
        // a long streaming request keeps the batch running underneath us
        let mut long = TcpStream::connect(addr)?;
        long.write_all(raw_post("{\"max_tokens\": 512, \"stream\": true}").as_bytes())?;
        let head = read_until(&mut long, b"\"pos\":")?;
        anyhow::ensure!(
            String::from_utf8_lossy(&head).contains("200 OK"),
            "long request non-200"
        );
        // the session is demonstrably mid-flight: stagger the short one in
        let j = post_generate_json(addr, probe_body)?;
        let admitted_pos = j.get("admitted_pos").and_then(Json::as_f64).unwrap_or(-1.0);
        drop(long); // hang up; the lane finishes its schedule regardless
        if admitted_pos > 0.0 {
            println!(
                "  attempt {attempt}: admitted at batch position {admitted_pos:.0} \
                 (mid-batch), steps {}",
                j.get("steps").and_then(Json::as_f64).unwrap_or(-1.0)
            );
            probe = Some(j);
            break;
        }
        println!("  attempt {attempt}: request landed in a fresh batch, retrying");
    }
    let probe = probe
        .ok_or_else(|| anyhow::anyhow!("no mid-batch admission observed in 3 attempts"))?;

    // fresh rerun of the identical request: the paper-level claim under
    // test is that admission position is semantically invisible, so the
    // checksum (and tokens, LM variant) must match bit-for-bit
    let fresh = post_generate_json(addr, probe_body)?;
    let (a, b) = (probe.get("checksum"), fresh.get("checksum"));
    anyhow::ensure!(
        a.is_some() && a == b,
        "checksum mismatch: admitted {a:?} vs fresh {b:?}"
    );
    anyhow::ensure!(
        probe.get("tokens") == fresh.get("tokens"),
        "token mismatch between admitted and fresh runs"
    );
    println!(
        "  bit-identical rollout: checksum {} == fresh rerun (admitted_pos {} vs {})",
        a.unwrap(),
        probe.get("admitted_pos").and_then(Json::as_f64).unwrap_or(-1.0),
        fresh.get("admitted_pos").and_then(Json::as_f64).unwrap_or(-1.0),
    );
    Ok(())
}

/// The session-paging probe: an oversubscribed arrival pattern — every
/// lane pinned by a long streaming request, then more work queued than
/// lanes exist — must force the scheduler to evict a lane into the pager,
/// admit the queued request, resume the evicted one in a later session,
/// and complete *everything* with checksums bit-identical to fresh
/// uninterrupted reruns. Emits the rows for BENCH_paging.json.
fn paging_probe(addr: std::net::SocketAddr) -> anyhow::Result<Json> {
    let info = {
        let mut s = TcpStream::connect(addr)?;
        s.write_all(b"GET /v1/info HTTP/1.1\r\n\r\n")?;
        let mut buf = String::new();
        s.read_to_string(&mut buf)?;
        Json::parse(buf.split("\r\n\r\n").nth(1).unwrap_or("{}"))
            .map_err(|e| anyhow::anyhow!("bad info: {e}"))?
    };
    let b = info.req_usize("B")?;
    anyhow::ensure!(
        info.get("paging").and_then(Json::as_bool) == Some(true),
        "server reports paging off"
    );
    let long_tokens = 384usize;
    let short_tokens = 16usize;
    let long_body = |seed: usize| {
        format!(
            "{{\"max_tokens\": {long_tokens}, \"sigma\": 0.05, \"seed\": {seed}, \
             \"stream\": true}}"
        )
    };

    let metric = |name| benchkit::scrape_metric(addr, name).unwrap_or(-1.0);
    let mut outcome = None;
    for attempt in 1..=3 {
        let seed0 = 500 + attempt * 10;
        let evict0 = metric("fi_evictions_total");
        // pin every lane: B long streaming requests, each confirmed
        // admitted by its first per-position event
        let mut longs = Vec::new();
        for i in 0..b {
            let mut s = TcpStream::connect(addr)?;
            s.write_all(raw_post(&long_body(seed0 + i)).as_bytes())?;
            read_until(&mut s, b"\"pos\":")?;
            longs.push(s);
        }
        // oversubscribe: two short requests with zero free lanes
        let short_body =
            format!("{{\"max_tokens\": {short_tokens}, \"sigma\": 0.05, \"seed\": 9}}");
        let shorts: Vec<Json> = (0..2)
            .map(|_| post_generate_json(addr, &short_body))
            .collect::<anyhow::Result<_>>()?;
        // every long must still complete (evicted or not)
        let mut tails = Vec::new();
        for mut s in longs {
            let mut raw = String::new();
            s.read_to_string(&mut raw)?;
            let payload = flash_inference::server::http::decode_chunked(
                raw.split("\r\n\r\n").nth(1).unwrap_or(""),
            );
            let done = payload
                .lines()
                .rfind(|l| l.contains("\"done\""))
                .ok_or_else(|| anyhow::anyhow!("no summary line"))?
                .to_string();
            let t = Json::parse(&done).map_err(|e| anyhow::anyhow!("bad tail: {e}"))?;
            anyhow::ensure!(t.get("error").is_none(), "long request errored: {t}");
            tails.push(t);
        }
        if metric("fi_evictions_total") > evict0 {
            outcome = Some((seed0, tails, shorts));
            break;
        }
        println!("  attempt {attempt}: longs drained before pressure built, retrying");
    }
    let (seed0, tails, shorts) =
        outcome.ok_or_else(|| anyhow::anyhow!("no eviction observed in 3 attempts"))?;

    for s in &shorts {
        anyhow::ensure!(
            s.get("admitted_pos").and_then(Json::as_f64).unwrap_or(-1.0) > 0.0,
            "short request did not admit into the running batch: {s}"
        );
    }
    let evicted = tails
        .iter()
        .filter(|t| t.get("evictions").and_then(Json::as_f64).unwrap_or(0.0) > 0.0)
        .count();
    anyhow::ensure!(evicted >= 1, "no long request reports an eviction");

    // the paging claim under test: eviction is semantically invisible —
    // every rollout's checksum equals a fresh uninterrupted rerun
    let mut rows = Vec::new();
    for (i, t) in tails.iter().enumerate() {
        let body =
            format!("{{\"max_tokens\": {long_tokens}, \"sigma\": 0.05, \"seed\": {}}}", seed0 + i);
        let fresh = post_generate_json(addr, &body)?;
        let (a, f) = (
            t.get("checksum").and_then(Json::as_f64),
            fresh.get("checksum").and_then(Json::as_f64),
        );
        anyhow::ensure!(
            a.is_some() && a == f,
            "seed {}: paged checksum {a:?} != fresh {f:?}",
            seed0 + i
        );
        rows.push(Json::from_pairs(vec![
            ("seed", Json::Num((seed0 + i) as f64)),
            ("max_tokens", Json::Num(long_tokens as f64)),
            ("evictions", t.get("evictions").cloned().unwrap_or(Json::Num(0.0))),
            ("queue_ms", t.get("queue_ms").cloned().unwrap_or(Json::Num(-1.0))),
            ("gen_ms", t.get("gen_ms").cloned().unwrap_or(Json::Num(-1.0))),
            ("checksum_match", Json::Bool(true)),
        ]));
    }
    anyhow::ensure!(metric("fi_resumes_total") >= 1.0, "no resume counted");
    println!(
        "  oversubscribed {} requests over {b} lanes: {evicted} eviction(s), \
         fi_evictions_total={:.0}, fi_resumes_total={:.0}, all checksums == fresh reruns",
        b + 2,
        metric("fi_evictions_total"),
        metric("fi_resumes_total"),
    );
    Ok(Json::from_pairs(vec![
        ("bench", Json::Str("paging".into())),
        ("meta", benchkit::bench_meta(None)),
        ("lanes", Json::Num(b as f64)),
        ("concurrent_requests", Json::Num((b + 2) as f64)),
        ("evictions_total", Json::Num(metric("fi_evictions_total"))),
        ("resumes_total", Json::Num(metric("fi_resumes_total"))),
        ("requests", Json::Arr(rows)),
    ]))
}

/// The session-cache probe (BENCH_session_cache.json): fold a lane out
/// of a running session (`suspend_folded` — the FutureFill fold at
/// suspend), spill the serialized FICK blob to disk, and resume it in a
/// **different** session at a **different** global position, requiring
/// per-position checksums bit-identical to an uninterrupted run. Times
/// the fold, the spill write, and the reload, so the O(p·(L−p)) fold
/// cost from DESIGN.md §6 has a measured counterpart per position.
fn session_cache_probe(artifacts: &str) -> anyhow::Result<Json> {
    use flash_inference::engine::{Engine, LaneInit, Method, SamplerCfg};
    use flash_inference::runtime::Runtime;

    let rt = Runtime::load(std::path::Path::new(artifacts))?;
    let engine = Engine::new(
        &rt,
        EngineOpts {
            method: Method::Flash,
            // direct τ: the folded deposit is bit-identical (DESIGN.md §6)
            tau: TauKind::RustDirect,
            async_mixer: true,
            ..Default::default()
        },
    )?;
    let mut pager = engine.make_pager(64);
    let spill_dir =
        std::env::temp_dir().join(format!("fi-session-cache-{}", std::process::id()));
    pager.set_spill_dir(&spill_dir)?;

    let lane = 0usize;
    let (len, admit_at, limit) = (128usize, 8usize, 64usize);
    let mk_init = |seed: u64| LaneInit {
        limit,
        sampler_cfg: Some(SamplerCfg::Synthetic { sigma: 0.25 }),
        seed: Some(seed),
        pending_seed: None,
    };

    let mut rows = Vec::new();
    // early / middle / late folds; each restores at an unaligned position
    let cases = [(16usize, 10usize), (32, 48), (56, 90)];
    for (k, &(suspend_at, restore_at)) in cases.iter().enumerate() {
        let seed = 900 + k as u64;
        let lane_pos = suspend_at - admit_at;
        let span = limit - lane_pos;

        // uninterrupted baseline
        let mut base = engine.session(len)?;
        for _ in 0..admit_at {
            base.step()?;
        }
        base.admit(lane, mk_init(seed))?;
        let mut want = Vec::with_capacity(limit);
        for _ in 0..limit {
            want.push(base.step()?.lane_checksums[lane]);
        }
        base.finish();

        // session 1: run to the suspend position, fold, spill, move on
        let mut s1 = engine.session(len)?;
        for _ in 0..admit_at {
            s1.step()?;
        }
        s1.admit(lane, mk_init(seed))?;
        let mut got = Vec::with_capacity(limit);
        for _ in 0..lane_pos {
            got.push(s1.step()?.lane_checksums[lane]);
        }
        let t = Instant::now();
        let ckpt = s1.suspend_folded(lane, &mut pager)?;
        let fold_ms = t.elapsed().as_secs_f64() * 1e3;
        anyhow::ensure!(ckpt.folded() && ckpt.span() == span, "unexpected checkpoint shape");
        let key = format!("cache-{k}");
        let blob = pager.serialize(&ckpt, None);
        let blob_bytes = blob.len();
        let t = Instant::now();
        pager.spill_blob(&key, &blob)?;
        let spill_ms = t.elapsed().as_secs_f64() * 1e3;
        pager.discard(ckpt);
        // the spilled copy must be byte-exact (it is the durable handle)
        let on_disk = std::fs::read_dir(&spill_dir)?
            .filter_map(|e| e.ok())
            .find(|e| e.path().extension().and_then(|x| x.to_str()) == Some("fick"))
            .ok_or_else(|| anyhow::anyhow!("no .fick file after spill"))?;
        anyhow::ensure!(std::fs::read(on_disk.path())? == blob, "spilled blob not byte-exact");
        for _ in 0..4 {
            s1.step()?;
        }
        s1.finish();

        // session 2: a fresh session at an arbitrary clock — reload the
        // spilled checkpoint and resume, no alignment wait
        let mut s2 = engine.session(len)?;
        for _ in 0..restore_at {
            s2.step()?;
        }
        let t = Instant::now();
        let (ckpt, _meta) = pager.load_spilled(&key)?;
        let reload_ms = t.elapsed().as_secs_f64() * 1e3;
        s2.restore(lane, ckpt, &mut pager)?;
        while !s2.lane_done(lane) {
            got.push(s2.step()?.lane_checksums[lane]);
        }
        s2.finish();
        anyhow::ensure!(
            want == got,
            "fold at {suspend_at} / resume at {restore_at}: checksums diverged from baseline"
        );
        println!(
            "  fold at pos {suspend_at} (span {span}) -> spill ({blob_bytes} B) -> resume at \
             pos {restore_at}: bit-identical; fold {fold_ms:.2}ms, reload {reload_ms:.2}ms"
        );
        rows.push(Json::from_pairs(vec![
            ("suspend_at", Json::Num(suspend_at as f64)),
            ("restore_at", Json::Num(restore_at as f64)),
            ("span", Json::Num(span as f64)),
            ("fold_ms", Json::Num(fold_ms)),
            ("spill_ms", Json::Num(spill_ms)),
            ("reload_ms", Json::Num(reload_ms)),
            ("blob_bytes", Json::Num(blob_bytes as f64)),
            ("checksum_match", Json::Bool(true)),
        ]));
    }
    let _ = std::fs::remove_dir_all(&spill_dir);
    Ok(Json::from_pairs(vec![
        ("bench", Json::Str("session_cache".into())),
        ("meta", benchkit::bench_meta(None)),
        ("limit", Json::Num(limit as f64)),
        ("rows", Json::Arr(rows)),
    ]))
}

fn main() -> anyhow::Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts/hyena".into());
    let cfg = ServerConfig {
        port: 0, // ephemeral
        artifacts: artifacts.clone().into(),
        engine: EngineOpts {
            // the admission probe compares checksums bit-for-bit across
            // different admission positions; that exactness holds for the
            // direct kernel's term-by-term accumulation (zeroed history
            // rows contribute exact +0.0s) but not for FFT tiles, which
            // mix a block's sources through transforms — so the smoke
            // pins rust-direct, which also keeps the async executor (and
            // its admission fence) on the exercised path
            tau: TauKind::RustDirect,
            ..ServerConfig::default().engine
        },
        ..Default::default()
    };
    println!("starting server on {artifacts} ...");
    let server = Server::start(cfg)?;
    println!("serving at http://{}", server.addr);

    // Poisson trace: 24 requests, ~2 rps, 16-128 tokens each
    let trace = WorkloadTrace::generate(TraceConfig {
        rate: 2.0,
        num_requests: 24,
        min_tokens: 16,
        max_tokens: 128,
        seed: 7,
    });
    println!(
        "replaying {} requests over ~{:.1}s ({} tokens total)",
        trace.requests.len(),
        trace.duration_s(),
        trace.total_tokens()
    );

    let t0 = Instant::now();
    let mut handles = Vec::new();
    let addr = server.addr;
    for req in trace.requests.clone() {
        handles.push(std::thread::spawn(move || {
            let wait = Duration::from_secs_f64(req.arrival_s);
            let since = t0.elapsed();
            if wait > since {
                std::thread::sleep(wait - since);
            }
            post_generate(addr, req.max_tokens)
        }));
    }

    let mut lat = LatencyRecorder::unbounded();
    let mut tokens = 0usize;
    let mut failures = 0usize;
    for h in handles {
        match h.join().unwrap() {
            Ok((toks, ms)) => {
                tokens += toks;
                lat.record_ns(ms * 1e6);
            }
            Err(e) => {
                eprintln!("request failed: {e:#}");
                failures += 1;
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("\n=== serving results ===");
    println!("requests: {} ok, {} failed", lat.count(), failures);
    println!("tokens:   {tokens} in {wall:.2}s  ->  {:.1} tok/s", tokens as f64 / wall);
    println!(
        "latency:  p50 {:.1}ms  p95 {:.1}ms  max {:.1}ms",
        lat.percentile_ns(50.0) / 1e6,
        lat.percentile_ns(95.0) / 1e6,
        lat.max_ns() / 1e6
    );
    anyhow::ensure!(failures == 0, "{failures} Poisson-replay requests failed");

    // one streaming request: tokens leave the engine per position over
    // chunked NDJSON instead of arriving once the whole rollout is done
    println!("\n=== streaming request (\"stream\": true) ===");
    let body = "{\"max_tokens\": 32, \"stream\": true}";
    let mut s = TcpStream::connect(addr)?;
    s.write_all(raw_post(body).as_bytes())?;
    let t0 = Instant::now();
    let mut raw = String::new();
    s.read_to_string(&mut raw)?;
    let ms = t0.elapsed().as_secs_f64() * 1e3;
    anyhow::ensure!(raw.contains("200 OK"), "streaming request non-200");
    let payload = flash_inference::server::http::decode_chunked(
        raw.split("\r\n\r\n").nth(1).unwrap_or(""),
    );
    let events = payload.lines().filter(|l| l.contains("\"pos\"")).count();
    let done = payload.lines().rfind(|l| l.contains("\"done\"")).unwrap_or("");
    println!("received {events} incremental events in {ms:.1}ms; summary: {done}");
    anyhow::ensure!(events == 32, "expected 32 events, got {events}");
    anyhow::ensure!(!done.contains("error"), "stream ended in error: {done}");

    // continuous admission: a staggered request must join the running
    // batch and still produce a bit-identical rollout
    println!("\n=== continuous admission probe (staggered requests) ===");
    admission_probe(addr)?;

    // session paging: oversubscribe the lanes and require evict + resume
    // with bit-identical rollouts end to end (BENCH_paging.json)
    println!("\n=== session paging probe (oversubscribed arrivals) ===");
    let paging_doc = paging_probe(addr)?;
    let out_path = benchkit::env_str("FI_PAGING_OUT", "BENCH_paging.json");
    std::fs::write(&out_path, paging_doc.to_string_pretty())?;
    println!("  wrote {out_path}");

    // position-independent checkpoints: fold -> spill -> resume in a
    // different session at a different position (BENCH_session_cache.json)
    println!("\n=== session-cache probe (fold -> spill -> cross-session resume) ===");
    let sc_doc = session_cache_probe(&artifacts)?;
    let sc_path = benchkit::env_str("FI_SESSION_CACHE_OUT", "BENCH_session_cache.json");
    std::fs::write(&sc_path, sc_doc.to_string_pretty())?;
    println!("  wrote {sc_path}");

    // scrape the server's own metrics
    let metrics = scrape_metrics(addr)?;
    println!("\n=== server metrics ===");
    for line in metrics.lines().filter(|l| !l.starts_with('#')) {
        println!("  {line}");
    }
    let metric = |name| benchkit::scrape_metric(addr, name).unwrap_or(-1.0);
    anyhow::ensure!(
        metric("fi_admissions_mid_batch") >= 1.0,
        "server never admitted a request mid-batch"
    );
    anyhow::ensure!(metric("fi_requests_failed") == 0.0, "failed requests");
    server.stop();
    println!("\nserving smoke: OK");
    Ok(())
}
