#!/usr/bin/env python3
"""Compare emitted BENCH_*.json files against their checked-in baselines.

Usage: bench_compare.py [--strict] [--baseline-dir DIR] CURRENT.json...

Works on every bench schema this repo emits, not just step_probe: any
top-level key holding an array of objects is treated as a row table
(`rows`, `sweep`, `modes`, `scenarios`, ...). Rows are joined to the
baseline on their "u" key when present, else by index, and every shared
numeric field gets a percent-delta column. Markdown goes to stdout and is
appended to $GITHUB_STEP_SUMMARY when set.

Per file:
  * baseline exists  -> diff table + attribution line from the `meta`
    header (sha/cpu/simd/workers); a cpu-brand mismatch against the
    baseline's meta is called out, since cross-machine deltas are noise.
  * baseline missing -> snapshot mode: print the current table and the
    `cp` one-liner to commit it. After all files, a single combined
    one-liner covers every missing baseline at once.
  * current missing  -> skipped with a note (benches are allowed to be
    conditional on artifacts), never an error.

Strict gates (--strict turns a failure into a nonzero exit; default is
report-only because shared CI runners are noisy):
  1. fence-wait (step_probe): at the largest U, the highest worker
     count's fence_wait_us must not exceed the single-worker value plus
     slack — the "fence-wait -> ~0 at large U" gate from DESIGN.md §5.
  2. crossover (tau_tile): measured_crossover_u must exist whenever the
     baseline measured one, and must sit within a 2x band of it — the
     direct<->fused-FFT switch point is the perf trajectory's headline
     number and silently losing or quadrupling it is a regression even
     when no single row trips a threshold.
  3. session-cache (serving smoke): every fold/spill/resume row must
     report checksum_match — a False is a correctness break, not noise,
     so it is reported even in report-only mode.
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt(v):
    if isinstance(v, bool):
        return str(v)
    if isinstance(v, float):
        return f"{v:.1f}"
    return str(v)


def emit(lines):
    text = "\n".join(lines) + "\n"
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text)


def row_tables(doc):
    """Every top-level key whose value is a non-empty list of dicts."""
    return {
        k: v
        for k, v in doc.items()
        if isinstance(v, list) and v and all(isinstance(r, dict) for r in v)
    }


def numeric_keys(rows):
    keys = []
    for row in rows:
        for k, v in row.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool) and k not in keys:
                keys.append(k)
    return keys


def join_rows(cur_rows, base_rows):
    """(cur, base-or-{}) pairs: join on "u" when both sides have it,
    else positionally."""
    if all("u" in r for r in cur_rows) and all("u" in r for r in base_rows):
        base_by_u = {r["u"]: r for r in base_rows}
        return [(r, base_by_u.get(r["u"], {})) for r in cur_rows]
    pairs = []
    for i, r in enumerate(cur_rows):
        pairs.append((r, base_rows[i] if i < len(base_rows) else {}))
    return pairs


def table_lines(title, cur_rows, base_rows):
    keys = numeric_keys(cur_rows)
    if not keys:
        return []
    lines = [f"**{title}**", ""]
    header = (["u"] if "u" in keys else []) + [k for k in keys if k != "u"]
    lines.append("| " + " | ".join(header) + " |")
    lines.append("|" + "---|" * len(header))
    for row, ref in join_rows(cur_rows, base_rows):
        cells = []
        for k in header:
            v = row.get(k)
            r = ref.get(k)
            if (
                k != "u"
                and isinstance(v, (int, float))
                and isinstance(r, (int, float))
                and r
            ):
                cells.append(f"{fmt(v)} ({(v - r) / r * 100.0:+.0f}%)")
            else:
                cells.append(fmt(v) if v is not None else "")
        lines.append("| " + " | ".join(cells) + " |")
    lines.append("")
    return lines


def meta_line(cur, base):
    meta = cur.get("meta")
    if not isinstance(meta, dict):
        return []
    bits = [f"sha `{str(meta.get('sha', '?'))[:12]}`", f"cpu: {meta.get('cpu', '?')}"]
    if meta.get("cpu_features"):
        bits.append(f"features: {meta['cpu_features']}")
    bits.append(
        "simd: "
        + (meta.get("simd_backend", "?") if meta.get("simd_compiled") else "off")
    )
    if meta.get("workers") is not None:
        bits.append(f"workers: {meta['workers']}")
    lines = ["_" + " | ".join(str(b) for b in bits) + "_"]
    base_meta = (base or {}).get("meta")
    if isinstance(base_meta, dict) and base_meta.get("cpu") and meta.get("cpu"):
        if base_meta["cpu"] != meta["cpu"]:
            lines.append(
                f"⚠️ cpu differs from baseline ({base_meta['cpu']}) — "
                "deltas below are cross-machine and not comparable."
            )
    lines.append("")
    return lines


def fence_gate(cur, base):
    """step_probe: multi-worker fence wait at the largest U must not exceed
    the single-worker value (target ~0). Self-contained in the current doc."""
    rows = cur.get("rows", [])
    workers = [int(w) for w in cur.get("workers", [])]
    if not rows or len(workers) < 2:
        return None
    last = max(rows, key=lambda r: r.get("u", 0))
    w_lo, w_hi = min(workers), max(workers)
    k_lo, k_hi = f"fence_wait_us_w{w_lo}", f"fence_wait_us_w{w_hi}"
    if k_lo not in last or k_hi not in last:
        return None
    lo, hi = float(last[k_lo]), float(last[k_hi])
    # absolute slack absorbs scheduler jitter when both values are ~0
    ok = hi <= lo + max(0.25 * lo, 5.0)
    return (
        ok,
        f"fence-wait gate ({'PASS' if ok else 'REGRESSION'}): at U={last.get('u')}, "
        f"{w_hi} workers wait {hi:.1f}us vs {lo:.1f}us single-worker",
    )


def crossover_gate(cur, base):
    """tau_tile: the measured direct<->fft crossover must not silently
    vanish or drift outside a 2x tolerance band of the baseline's."""
    if "measured_crossover_u" not in cur:
        return None
    got = cur.get("measured_crossover_u")
    want = (base or {}).get("measured_crossover_u")
    if want is None:
        if base:
            return (True, "crossover gate (PASS): baseline has no measured crossover")
        return None  # snapshot mode: nothing to band against
    if got is None:
        return (
            False,
            f"crossover gate (REGRESSION): baseline measured U={want:g} "
            "but the current run found none in its sweep",
        )
    ok = want / 2.0 <= float(got) <= want * 2.0
    return (
        ok,
        f"crossover gate ({'PASS' if ok else 'REGRESSION'}): measured U={got:g} "
        f"vs baseline U={want:g} (2x band)",
    )


def session_cache_gate(cur, base):
    """session_cache: each fold -> spill -> cross-session resume must be
    bit-identical; the probe itself fails hard, but a hand-edited or stale
    JSON must not read as a pass."""
    if cur.get("bench") != "session_cache":
        return None
    rows = cur.get("rows", [])
    if not rows:
        return None
    bad = [r.get("suspend_at") for r in rows if r.get("checksum_match") is not True]
    ok = not bad
    detail = (
        f"all {len(rows)} folds resumed bit-identically"
        if ok
        else f"checksum mismatch at suspend positions {bad}"
    )
    return (ok, f"session-cache gate ({'PASS' if ok else 'REGRESSION'}): {detail}")


GATES = (fence_gate, crossover_gate, session_cache_gate)


def compare_one(cur_path, base_path):
    """Returns (failed_gates, missing_baseline_pair_or_None)."""
    if not os.path.exists(cur_path):
        emit([f"### {os.path.basename(cur_path)}: not produced by this run — skipped"])
        return 0, None

    cur = load(cur_path)
    name = cur.get("bench", os.path.basename(cur_path))
    base = load(base_path) if os.path.exists(base_path) else None

    if base is None:
        lines = [
            f"### {name}: no baseline snapshot",
            "",
            f"`{base_path}` does not exist yet — running in snapshot mode.",
            f"To enable PR-over-PR comparison: `cp {cur_path} {base_path}`.",
            "",
        ]
        for title, rows in row_tables(cur).items():
            lines += table_lines(title, rows, [])
        emit(lines)
    else:
        lines = [f"### {name}: current vs baseline (`{base_path}`)", ""]
        lines += meta_line(cur, base)
        base_tables = row_tables(base)
        for title, rows in row_tables(cur).items():
            lines += table_lines(title, rows, base_tables.get(title, []))
        emit(lines)

    failed = 0
    for gate in GATES:
        verdict = gate(cur, base)
        if verdict is None:
            continue
        ok, text = verdict
        emit([text])
        if not ok:
            failed += 1
    return failed, (None if base is not None else (cur_path, base_path))


def main(argv):
    strict = "--strict" in argv
    args = [a for a in argv if not a.startswith("--")]
    if "--baseline-dir" in argv:
        base_dir = argv[argv.index("--baseline-dir") + 1]
        args = [a for a in args if a != base_dir]
    else:
        base_dir = os.path.join("benches", "baselines")
    if not args:
        print(__doc__)
        return 2

    failed = 0
    missing = []
    for cur_path in args:
        base_path = os.path.join(base_dir, os.path.basename(cur_path))
        f, miss = compare_one(cur_path, base_path)
        failed += f
        if miss:
            missing.append(miss)

    if missing:
        cps = " && ".join(f"cp {c} {b}" for c, b in missing)
        emit(
            [
                "To commit every missing baseline in one go (run from `rust/`):",
                "",
                f"    {cps}",
                "",
            ]
        )
    if failed:
        emit([f"{failed} strict gate(s) failed" + ("" if strict else " (report-only)")])
    return 1 if (strict and failed) else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
