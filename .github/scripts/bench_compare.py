#!/usr/bin/env python3
"""Compare a bench JSON against its checked-in baseline snapshot.

Usage: bench_compare.py CURRENT.json [BASELINE.json] [--strict]

Modes:
  * baseline exists  -> per-row numeric diff table (markdown, appended to
    $GITHUB_STEP_SUMMARY when set, always printed to stdout), plus the
    multi-worker fence-wait check: at the largest U, the highest worker
    count's fence_wait_us must not exceed the single-worker value
    (the "fence-wait -> ~0 at large U" gate from DESIGN.md §5).
  * baseline missing -> snapshot mode: print the current rows and how to
    commit the baseline; exit 0.

The diff is report-only by default (shared CI runners are noisy); pass
--strict to turn a fence-wait regression into a nonzero exit.
"""

import json
import os
import sys


def load(path):
    with open(path) as f:
        return json.load(f)


def fmt(v):
    if isinstance(v, float):
        return f"{v:.1f}"
    return str(v)


def emit(lines):
    text = "\n".join(lines) + "\n"
    print(text)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(text)


def numeric_keys(rows):
    keys = []
    for row in rows:
        for k, v in row.items():
            if isinstance(v, (int, float)) and k not in keys:
                keys.append(k)
    return keys


def fence_check(doc):
    """The machine-checkable gate: multi-worker fence wait at the largest
    U must not exceed the single-worker baseline (target ~0)."""
    rows = doc.get("rows", [])
    workers = [int(w) for w in doc.get("workers", [])]
    if not rows or len(workers) < 2:
        return None
    last = max(rows, key=lambda r: r.get("u", 0))
    w_lo, w_hi = min(workers), max(workers)
    k_lo, k_hi = f"fence_wait_us_w{w_lo}", f"fence_wait_us_w{w_hi}"
    if k_lo not in last or k_hi not in last:
        return None
    lo, hi = float(last[k_lo]), float(last[k_hi])
    # absolute slack absorbs scheduler jitter when both values are ~0
    ok = hi <= lo + max(0.25 * lo, 5.0)
    return {
        "u": last.get("u"),
        "w_lo": w_lo,
        "w_hi": w_hi,
        "fence_lo": lo,
        "fence_hi": hi,
        "ok": ok,
    }


def main(argv):
    strict = "--strict" in argv
    args = [a for a in argv if not a.startswith("--")]
    if not args:
        print(__doc__)
        return 2
    cur_path = args[0]
    base_path = (
        args[1]
        if len(args) > 1
        else os.path.join("benches", "baselines", os.path.basename(cur_path))
    )

    cur = load(cur_path)
    name = cur.get("bench", os.path.basename(cur_path))
    cur_rows = cur.get("rows", [])

    if not os.path.exists(base_path):
        lines = [
            f"### {name}: no baseline snapshot",
            "",
            f"`{base_path}` does not exist yet — running in snapshot mode.",
            "To enable PR-over-PR comparison, commit the current JSON as the "
            f"baseline: `cp {cur_path} {base_path}`.",
            "",
        ]
        keys = numeric_keys(cur_rows)
        if keys:
            lines.append("| " + " | ".join(keys) + " |")
            lines.append("|" + "---|" * len(keys))
            for row in cur_rows:
                lines.append(
                    "| " + " | ".join(fmt(row.get(k, "")) for k in keys) + " |"
                )
        emit(lines)
        gate = fence_check(cur)
        if gate:
            status = "PASS" if gate["ok"] else "REGRESSION"
            emit(
                [
                    f"fence-wait gate ({status}): U={gate['u']} "
                    f"w{gate['w_hi']}={gate['fence_hi']:.1f}us vs "
                    f"w{gate['w_lo']}={gate['fence_lo']:.1f}us"
                ]
            )
            if strict and not gate["ok"]:
                return 1
        return 0

    base = load(base_path)
    base_by_u = {r.get("u"): r for r in base.get("rows", [])}
    keys = numeric_keys(cur_rows)
    lines = [f"### {name}: current vs baseline (`{base_path}`)", ""]
    header = ["u"] + [k for k in keys if k != "u"]
    lines.append("| " + " | ".join(header) + " |")
    lines.append("|" + "---|" * len(header))
    for row in cur_rows:
        u = row.get("u")
        ref = base_by_u.get(u, {})
        cells = [fmt(u)]
        for k in header[1:]:
            v = row.get(k)
            r = ref.get(k)
            if isinstance(v, (int, float)) and isinstance(r, (int, float)) and r:
                cells.append(f"{fmt(v)} ({(v - r) / r * 100.0:+.0f}%)")
            else:
                cells.append(fmt(v) if v is not None else "")
        lines.append("| " + " | ".join(cells) + " |")
    lines.append("")
    emit(lines)

    gate = fence_check(cur)
    if gate:
        status = "PASS" if gate["ok"] else "REGRESSION"
        emit(
            [
                f"fence-wait gate ({status}): at U={gate['u']}, "
                f"{gate['w_hi']} workers wait {gate['fence_hi']:.1f}us vs "
                f"{gate['fence_lo']:.1f}us single-worker"
            ]
        )
        if strict and not gate["ok"]:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
