#!/usr/bin/env python3
"""Render BENCH_*.json documents as markdown tables into $GITHUB_STEP_SUMMARY.

Each bench JSON is a flat object of scalar metadata plus one or more
arrays of row-objects (e.g. ``rows``, ``sweep``, ``arrival_modes``).
Scalars become an inline code line, every row array becomes a table, so
the perf trajectory is readable per-run in the Actions UI instead of only
as a downloadable artifact.

Usage: bench_to_summary.py BENCH_a.json [BENCH_b.json ...]
"""

import json
import os
import sys


def fmt(v):
    if isinstance(v, bool):
        return "yes" if v else "no"
    if isinstance(v, float):
        return f"{v:.4g}"
    if v is None:
        return "-"
    return str(v)


def table(rows):
    cols = list(rows[0].keys())
    lines = [
        "| " + " | ".join(cols) + " |",
        "|" + "---|" * len(cols),
    ]
    for r in rows:
        lines.append("| " + " | ".join(fmt(r.get(c)) for c in cols) + " |")
    return "\n".join(lines)


def emit(path, out):
    if not os.path.exists(path):
        print(f"### {os.path.basename(path)}\n\n_missing (bench did not run)_\n", file=out)
        return
    with open(path) as f:
        doc = json.load(f)
    name = doc.get("bench", os.path.basename(path))
    print(f"### bench: {name}\n", file=out)
    scalars = {k: v for k, v in doc.items() if not isinstance(v, (list, dict)) and k != "bench"}
    if scalars:
        print(" ".join(f"`{k}={fmt(v)}`" for k, v in scalars.items()) + "\n", file=out)
    arrays = {k: v for k, v in doc.items() if isinstance(v, list) and v and isinstance(v[0], dict)}
    for key, rows in arrays.items():
        if len(arrays) > 1:
            print(f"**{key}**\n", file=out)
        print(table(rows) + "\n", file=out)


def main():
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if not summary:
        emit_to = sys.stdout
        for path in sys.argv[1:]:
            emit(path, emit_to)
        return
    with open(summary, "a") as out:
        for path in sys.argv[1:]:
            emit(path, out)


if __name__ == "__main__":
    main()
